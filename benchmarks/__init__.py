"""Benchmark harness: one module per paper table/figure + the roofline
analysis over the dry-run artifacts.  ``python -m benchmarks.run`` runs all."""
