"""Real wall-clock microbenchmarks on this host (CPU backend).

These measure the actual JAX engine (not the simulator): spec-decode round
latency, plain decode, verify/commit overhead, and kernel interpret-mode
sanity.  Absolute numbers are CPU-container-specific; the derived columns
(speculative speedup factor, acceptance) are the meaningful outputs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.spec_decode import spec_round
from repro.models import model as M
from repro.models.transformer import init_cache


def _tiny(vocab=127, d=128, layers=4):
    return ModelConfig(name="bench-target", arch_type="dense",
                       n_layers=layers, d_model=d, n_heads=4, n_kv_heads=2,
                       d_ff=d * 3, vocab_size=vocab, dtype="float32",
                       remat=False)


def _draft(vocab=127):
    return ModelConfig(name="bench-draft", arch_type="dense", n_layers=2,
                       d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
                       vocab_size=vocab, dtype="float32", remat=False)


def _time(fn, n=5):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(rows: list):
    tcfg, dcfg = _tiny(), _draft()
    tp = M.init_params(tcfg, jax.random.PRNGKey(0))
    dp = M.init_params(dcfg, jax.random.PRNGKey(1))
    B, L, m = 8, 32, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                              tcfg.vocab_size)
    maxlen = 256

    prefill = jax.jit(M.prefill, static_argnums=(1,))
    decode_step = jax.jit(M.decode_step, static_argnums=(1,))
    spec = jax.jit(spec_round, static_argnames=(
        "target_cfg", "draft_cfg", "n_cand", "mesh", "sample"))

    tc = init_cache(tcfg, B, maxlen)
    dc = init_cache(dcfg, B, maxlen)
    lg, tc = prefill(tp, tcfg, toks, tc)
    _, dc = prefill(dp, dcfg, toks, dc)
    t_next = jnp.argmax(lg, -1)

    us_plain = _time(lambda: decode_step(tp, tcfg, tc, t_next[:, None])[0])
    rows.append(("engine_plain_decode_step", us_plain, "1 token/seq"))

    state = {"tc": tc, "dc": dc, "t": t_next}

    def one_round():
        r = spec(tp, tcfg, state["tc"], dp, dcfg, state["dc"], state["t"], m)
        state["tc"], state["dc"] = r["target_cache"], r["draft_cache"]
        state["t"] = r["t_next"]
        return r["n_emitted"]

    us_round = _time(one_round)
    emitted = float(np.asarray(one_round()).mean())
    rows.append(("engine_spec_round", us_round,
                 f"emits {emitted:.2f} tok/seq/round (m={m})"))
    rows.append(("engine_tokens_per_round_vs_plain",
                 emitted * us_plain / us_round,
                 "engine-level speculative speedup on CPU (>1 = win even "
                 "without offload slack)"))

    # kernel interpret sanity timings
    from repro.kernels import ops
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 128, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 128, 64))
    us_fa = _time(lambda: ops.flash_attention(q, k, v, block_q=64,
                                              block_k=64, interpret=True),
                  n=2)
    rows.append(("kernel_flash_attention_interpret", us_fa,
                 "(interpret mode: correctness only)"))
