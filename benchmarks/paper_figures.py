"""Reproductions of every SpecOffload table/figure via the calibrated
simulator (see EXPERIMENTS.md §Paper-claims for the side-by-side)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import MISTRAL_7B, MIXTRAL_8X7B, MIXTRAL_8X22B
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.core.spec_decode import (acceptance_pmf, expected_generated,
                                    expected_generated_paper_eq12)
from repro.data.pipeline import DATASET_STATS
from repro.sim.hardware import ENV1, ENV2
from repro.sim.simulator import (ablation, decode_timeline, disk_mode,
                                 end_to_end, memory_sweep)

GEN_LEN = 48

# the paper's measured numbers for the comparison columns
PAPER = {
    "fig5_env1_8x7b": {"specoffload": 24.74, "flexgen": 9.74,
                       "accelerate": 5.27, "deepspeed": 5.25,
                       "fiddler": 6.12},
    "fig5_env2_8x22b": {"specoffload": 5.91},
    "fig6_util": 0.5867,
    "fig1_util": {"flexgen": 0.13, "accelerate": 0.072, "deepspeed": 0.082,
                  "fiddler": 0.071},
    "table4_8x7b": {"all": 24.743, "no_policy": 15.624, "serial_sd": 17.048,
                    "no_sd": 12.369},
    "table4_8x22b": {"all": 5.911, "no_policy": 3.486, "serial_sd": 4.146,
                     "no_sd": 1.698},
    "fig8_ratio": 0.293,
}


def _wl(dataset="summeval", gen_len=GEN_LEN, p=0.75):
    return Workload(int(DATASET_STATS[dataset]["s_avg"]), gen_len, p)


def fig5_throughput(rows: list):
    wl = _wl()
    res1 = end_to_end(MIXTRAL_8X7B, MISTRAL_7B, ENV1, wl,
                      Policy(80, 192, 8, 8))
    for k, r in res1.items():
        ours, paper = r.throughput, PAPER["fig5_env1_8x7b"].get(k)
        rows.append(("fig5_env1_8x7b_" + k, ours,
                     f"paper={paper}" if paper else ""))
    spec = res1["specoffload"].throughput
    best_base = max(r.throughput for k, r in res1.items()
                    if k != "specoffload")
    rows.append(("fig5_env1_speedup_vs_best", spec / best_base,
                 "paper=2.53x"))

    res2 = end_to_end(MIXTRAL_8X22B, MISTRAL_7B, ENV2, wl,
                      Policy(16, 64, 8, 8))
    rows.append(("fig5_env2_8x22b_specoffload",
                 res2["specoffload"].throughput, "paper=5.91"))
    best2 = max(r.throughput for k, r in res2.items() if k != "specoffload")
    rows.append(("fig5_env2_speedup_vs_best",
                 res2["specoffload"].throughput / best2, "paper=2.54x"))


def fig1_fig6_utilization(rows: list):
    wl = _wl()
    res = end_to_end(MIXTRAL_8X7B, MISTRAL_7B, ENV1, wl,
                     Policy(80, 192, 8, 8))
    spec_u = res["specoffload"].gpu_util
    rows.append(("fig6_gpu_util_specoffload", spec_u, "paper=0.5867"))
    for k in ("flexgen", "accelerate", "deepspeed", "fiddler"):
        rows.append((f"fig1_gpu_util_{k}", res[k].gpu_util,
                     f"paper={PAPER['fig1_util'][k]}"))
    rows.append(("fig6_util_ratio_vs_flexgen",
                 spec_u / res["flexgen"].gpu_util, "paper=4.49x"))
    tl = decode_timeline(MIXTRAL_8X7B, MISTRAL_7B, ENV1, wl,
                         Policy(80, 192, 8, 8))
    rows.append(("fig7_draft_burst_fraction", tl.busy_fraction(),
                 "paper~26s/28s=0.93"))


def fig2_memory(rows: list):
    wl = _wl()
    sweep = memory_sweep(MIXTRAL_8X7B, ENV1, wl, [0.9, 0.166])
    drop = 1 - sweep[1]["throughput"] / sweep[0]["throughput"]
    rows.append(("fig2_8x7b_thr_drop_for_5.4x_mem_cut", drop,
                 "paper=0.13 (marginal utility of GPU memory)"))
    sweep22 = memory_sweep(MIXTRAL_8X22B, ENV1, wl, [0.9, 0.31])
    drop22 = 1 - sweep22[1]["throughput"] / sweep22[0]["throughput"]
    rows.append(("fig2_8x22b_thr_drop_for_2.9x_mem_cut", drop22,
                 "paper=0.05"))


def table3_breakdown(rows: list):
    wl = _wl()
    pl = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
    rep = pl.evaluate(Policy(80, 192, 8, 8), wl)
    import math
    slots = 2 * math.ceil(wl.gen_len / rep.expected_tokens)
    rows.append(("table3_P_total_s", rep.t_prefill, "paper=183.28"))
    rows.append(("table3_D_total_s", rep.t_decode, "paper=569.21"))
    rows.append(("table3_D_compute_gpu_draft_s",
                 min(rep.t_draft, rep.detail["t_round"]) * slots,
                 "paper=489.02"))
    rows.append(("table3_D_compute_cpu_s",
                 rep.detail["t_attn_host"] * slots, "paper=531.23"))
    rows.append(("table3_D_weight_read_s",
                 rep.detail["t_ffn_stream"] * slots, "paper=236.2"))


def table4_ablation(rows: list):
    wl = _wl()
    ab = ablation(MIXTRAL_8X7B, MISTRAL_7B, ENV1, wl,
                  Policy(80, 192, 8, 8), Policy(50, 256, 5, 2))
    for k, r in ab.items():
        rows.append((f"table4_8x7b_{k}", r.throughput,
                     f"paper={PAPER['table4_8x7b'][k]}"))
    ab2 = ablation(MIXTRAL_8X22B, MISTRAL_7B, ENV2, wl,
                   Policy(16, 64, 8, 8), Policy(16, 32, 6, 6))
    for k, r in ab2.items():
        rows.append((f"table4_8x22b_{k}", r.throughput,
                     f"paper={PAPER['table4_8x22b'][k]}"))


def fig8_disk(rows: list):
    wl = _wl()
    dm = disk_mode(MIXTRAL_8X22B, MISTRAL_7B, ENV1, wl, Policy(16, 64, 8, 8))
    rows.append(("fig8_disk_ratio", dm["ratio"], "paper=0.293"))
    rows.append(("fig8_disk_bytes_gib", dm["disk_bytes_gib"], ""))


def policy_sweep(rows: list):
    """Tables 5-10: throughput across the policy grid; checks the planner's
    qualitative findings (n_cand sweet spot, batch knee)."""
    wl = _wl("humaneval")
    pl = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
    # Table 5 rows 2-6: (80,160,6,m) for m in 1,2,4,6,8 -> monotone rise
    thr = [pl.evaluate(Policy(80, 160, 6, m), wl).throughput
           for m in (1, 2, 4, 6, 8)]
    rows.append(("table5_ncand_monotone_1to6",
                 float(np.all(np.diff(thr[:4]) > 0)),
                 f"paper rows 2-5 rise 15.9->33.7 (ours {thr[0]:.1f}->"
                 f"{thr[3]:.1f})"))
    best = pl.search(wl)
    rows.append(("table5_planner_best_thr", best.throughput,
                 f"policy={best.policy.astuple()} paper best 34.7 "
                 f"@(80,256,10,6)"))
    # oversized decode batch collapses (paper rows 36-45)
    big = pl.evaluate(Policy(80, 320, 5, 1), wl)
    rows.append(("table5_bs320_overload_feasible", float(big.feasible),
                 "paper: 320 collapses to 4.4 tok/s (mem/cpu overload)"))


def acceptance_model(rows: list):
    for p in (0.3, 0.7, 0.9):
        for m in (4, 8):
            e = expected_generated(p, m)
            e_paper = expected_generated_paper_eq12(p, m)
            pmf = acceptance_pmf(p, m)
            mc = float((np.arange(1, m + 2) * np.asarray(pmf)).sum())
            rows.append((f"accept_E[n]_p{p}_m{m}", e,
                         f"pmf_sum={mc:.3f} paper_eq12={e_paper:.3f} "
                         f"(erratum: printed closed form != own pmf)"))


def run(rows: list):
    fig5_throughput(rows)
    fig1_fig6_utilization(rows)
    fig2_memory(rows)
    table3_breakdown(rows)
    table4_ablation(rows)
    fig8_disk(rows)
    policy_sweep(rows)
    acceptance_model(rows)
