"""Continuous-batching serving benchmark: Poisson arrival trace through
the slot scheduler on the reduced CPU config.

Reports slot occupancy, TTFT / end-to-end latency percentiles, sustained
tokens/s, and the fused-step compile count (must stay 1 across all
retirements/admissions).  Row format matches benchmarks/run.py:
``(name, value, derived)``.

    PYTHONPATH=src python -m benchmarks.serving_bench [--requests N]
"""
from __future__ import annotations

import numpy as np


def run(rows: list, requests: int = 10, gen: int = 8, rate: float = 2.0,
        seed: int = 0) -> dict:
    from repro.configs.base import MIXTRAL_8X7B, MISTRAL_7B
    from repro.serving.engine import (SchedulerConfig, ServingEngine,
                                      latency_percentiles)
    from repro.serving.trace import poisson_requests

    tcfg = MIXTRAL_8X7B.reduced(d_model=64)
    dcfg = MISTRAL_7B.reduced(d_model=32, vocab=tcfg.vocab_size)
    # length_bucket pads admitted prompts to one shape so the trace
    # measures scheduler behavior, not per-length prefill compiles (the
    # benchmark doesn't assert raw-prompt losslessness)
    eng = ServingEngine(tcfg, dcfg,
                        config=SchedulerConfig(max_batch=2, n_cand=2,
                                               length_bucket=16))
    eng.init_from_seed(seed)

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, tcfg.vocab_size,
                            int(rng.integers(8, 17))).astype(np.int32)
               for _ in range(requests)]
    gens = rng.integers(max(2, gen // 2), gen + 1, requests)
    for r in poisson_requests(prompts, gens.tolist(), rate, seed):
        eng.submit(r)

    done = eng.run()
    st = eng.stats()
    ttft = latency_percentiles(done, "ttft_s")
    e2e = latency_percentiles(done, "latency_s")
    rows.append(("serving/occupancy", st["mean_occupancy"], "measured"))
    rows.append(("serving/tok_per_s", eng.throughput(done), "measured"))
    rows.append(("serving/ttft_p50_s", ttft["p50"], "measured"))
    rows.append(("serving/ttft_p95_s", ttft["p95"], "measured"))
    rows.append(("serving/e2e_p50_s", e2e["p50"], "measured"))
    rows.append(("serving/e2e_p95_s", e2e["p95"], "measured"))
    rows.append(("serving/fused_compiles", float(st["fused_compiles"]),
                 "measured"))
    return {"done": done, "stats": st, "ttft": ttft, "e2e": e2e}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    args = ap.parse_args()
    rows: list = []
    out = run(rows, args.requests, args.gen, args.rate)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    st = out["stats"]
    print(f"\n{len(out['done'])} requests, {st['rounds']} rounds, "
          f"occupancy {st['mean_occupancy']:.2f}, "
          f"{st['fused_compiles']} fused compile(s)")


if __name__ == "__main__":
    main()
