"""Continuous-batching serving benchmark: Poisson arrival trace through
the slot scheduler on the reduced CPU config.

Reports slot occupancy, TTFT / end-to-end latency percentiles, sustained
tokens/s, peak resident target-KV bytes, and the fused-step compile count
(must stay 1 across all retirements/admissions).  Row format matches
benchmarks/run.py: ``(name, value, derived)``.

    PYTHONPATH=src python -m benchmarks.serving_bench [--requests N]
    # open-loop asyncio serving (2 tenants, bounded admission queue,
    # priority preemption) vs closed-loop run() on the same trace
    #   -> BENCH_serving_async.json
    PYTHONPATH=src python -m benchmarks.serving_bench --async
    # paged-vs-contiguous A/B on the same trace -> BENCH_serving_paged.json
    PYTHONPATH=src python -m benchmarks.serving_bench --compare [--out F]
    # chain-vs-tree speculation A/B at equal candidate budget
    #   -> BENCH_serving_tree.json
    PYTHONPATH=src python -m benchmarks.serving_bench --compare-spec
    # observability run: Perfetto trace + metrics snapshot + utilization
    # digest (paper's bubble/GPU-busy metric) -> BENCH_serving_obs.json
    PYTHONPATH=src python -m benchmarks.serving_bench \\
        --trace-out trace.json --metrics-out metrics.json
"""
from __future__ import annotations

import numpy as np


def run(rows: list, requests: int = 10, gen: int = 8, rate: float = 2.0,
        seed: int = 0, paged: bool = True, kv_quant_cold: bool = False,
        prefix: str = "serving", trace: bool = False, n_cand: int = 2,
        spec_tree: tuple | None = None, vocab: int | None = None,
        request_timeline: bool = False) -> dict:
    import dataclasses

    from repro.configs.base import MIXTRAL_8X7B, MISTRAL_7B
    from repro.serving.engine import (SchedulerConfig, ServingEngine,
                                      latency_percentiles)
    from repro.serving.trace import poisson_requests

    tcfg = MIXTRAL_8X7B.reduced(d_model=64, **({"vocab": vocab} if vocab
                                               else {}))
    dcfg = MISTRAL_7B.reduced(d_model=32, vocab=tcfg.vocab_size)
    if spec_tree is not None:
        # tree speculation needs an all-attention draft; swap the SWA
        # pattern for full attention at the same size
        dcfg = dataclasses.replace(dcfg, layer_pattern=("attn",) * 2,
                                   n_layers=2)
    # length_bucket pads admitted prompts to one shape so the trace
    # measures scheduler behavior, not per-length prefill compiles (the
    # benchmark doesn't assert raw-prompt losslessness)
    eng = ServingEngine(tcfg, dcfg,
                        config=SchedulerConfig(max_batch=2, n_cand=n_cand,
                                               spec_tree=spec_tree,
                                               length_bucket=16,
                                               paged=paged,
                                               kv_quant_cold=kv_quant_cold,
                                               trace=trace,
                                               request_timeline=
                                               request_timeline))
    eng.init_from_seed(seed)

    rng = np.random.default_rng(seed)
    # heavy-tailed prompt mix: mostly short chats plus occasional long
    # documents.  The contiguous layout must size every slot for the
    # tail; the paged pool only holds blocks each sequence actually uses.
    lens = [int(rng.integers(48, 81)) if rng.random() < 0.25
            else int(rng.integers(8, 17)) for _ in range(requests)]
    prompts = [rng.integers(0, tcfg.vocab_size, L).astype(np.int32)
               for L in lens]
    gens = rng.integers(max(2, gen // 2), gen + 1, requests)
    for r in poisson_requests(prompts, gens.tolist(), rate, seed):
        eng.submit(r)

    done = eng.run()
    st = eng.stats()
    ttft = latency_percentiles(done, "ttft_s")
    e2e = latency_percentiles(done, "latency_s")
    kv = st["kv"]
    rows.append((f"{prefix}/occupancy", st["mean_occupancy"], "measured"))
    rows.append((f"{prefix}/tok_per_s", eng.throughput(done), "measured"))
    rows.append((f"{prefix}/ttft_p50_s", ttft["p50"], "measured"))
    rows.append((f"{prefix}/ttft_p95_s", ttft["p95"], "measured"))
    rows.append((f"{prefix}/e2e_p50_s", e2e["p50"], "measured"))
    rows.append((f"{prefix}/e2e_p95_s", e2e["p95"], "measured"))
    rows.append((f"{prefix}/peak_kv_bytes", float(kv["peak_kv_bytes"]),
                 "measured"))
    rows.append((f"{prefix}/fused_compiles", float(st["fused_compiles"]),
                 "measured"))
    return {"done": done, "stats": st, "ttft": ttft, "e2e": e2e,
            "engine": eng}


def _summary(out: dict) -> dict:
    """JSON-friendly digest of one run() result."""
    st = out["stats"]
    kv = {k: v for k, v in st["kv"].items() if k != "allocators"}
    return {
        "requests": len(out["done"]),
        "rounds": st["rounds"],
        "occupancy": st["mean_occupancy"],
        "tok_per_s": st["tok_per_s"],
        "ttft_s": out["ttft"],
        "e2e_s": out["e2e"],
        "decode_s": {  # first token -> last token
            k: float(v) for k, v in zip(
                ("p50", "p95", "p99"),
                np.percentile([r.decode_s for r in out["done"]],
                              (50, 95, 99)))},
        "fused_compiles": st["fused_compiles"],
        "rejected": st["rejected"],
        "kv": kv,
        "peak_kv_bytes": float(kv["peak_kv_bytes"]),
    }


def compare(requests: int = 10, gen: int = 8, rate: float = 2.0,
            seed: int = 0) -> dict:
    """Contiguous vs paged vs paged+int8 on the *same* Poisson trace."""
    variants = {
        "contiguous": dict(paged=False),
        "paged": dict(paged=True),
        "paged_int8_cold": dict(paged=True, kv_quant_cold=True),
    }
    report: dict = {"trace": {"requests": requests, "gen": gen,
                              "rate_rps": rate, "seed": seed,
                              "config": "MIXTRAL_8X7B.reduced(d_model=64)"
                                        " / max_batch=2 x2, n_cand=2"}}
    for name, kw in variants.items():
        rows: list = []
        out = run(rows, requests, gen, rate, seed, prefix=name, **kw)
        report[name] = _summary(out)
    base, pag = report["contiguous"], report["paged"]
    report["verdict"] = {
        "peak_kv_reduction": 1.0 - pag["peak_kv_bytes"]
        / base["peak_kv_bytes"],
        "tok_per_s_ratio": pag["tok_per_s"] / base["tok_per_s"],
        "int8_peak_kv_reduction": 1.0
        - report["paged_int8_cold"]["peak_kv_bytes"]
        / base["peak_kv_bytes"],
    }
    return report


def _accept_per_pass(eng, mode: str) -> dict:
    """Accepted-candidates-per-target-pass from the acceptance counters:
    emitted tokens per verify pass = accepted/rounds + 1 (the bonus)."""
    snap = eng.metrics()["metrics"]["counters"]
    lab = f'{{mode="{mode}"}}'
    acc = snap.get("spec_tokens_accepted_total", {}).get(lab, 0.0)
    waste = snap.get("spec_tokens_wasted_total", {}).get(lab, 0.0)
    rounds = snap.get("spec_verify_rounds_total", {}).get(lab, 0.0)
    return {"accepted_total": acc, "wasted_total": waste,
            "verify_rounds": rounds,
            "accepted_per_pass": acc / max(rounds, 1.0),
            "emitted_per_pass": acc / max(rounds, 1.0) + 1.0,
            "waste_frac": waste / max(acc + waste, 1.0)}


def compare_spec(requests: int = 10, gen: int = 8, rate: float = 2.0,
                 seed: int = 0, tree: tuple = (3, 2),
                 vocab: int = 13) -> dict:
    """Chain vs tree speculation on the *same* Poisson trace at equal
    candidate budget (chain n_cand = tree nodes - 1).

    A small vocab makes the tiny random draft/target pair agree often
    enough that acceptance behavior is measurable; the tree's extra
    siblings then raise the chance *some* path survives each depth, which
    is exactly the accepted-tokens-per-target-pass gain the planner's
    tree model predicts at low acceptance rates.
    """
    from repro.core.spec_decode import tree_n_nodes

    budget = tree_n_nodes(tree) - 1         # candidates per verify pass
    report: dict = {"trace": {"requests": requests, "gen": gen,
                              "rate_rps": rate, "seed": seed,
                              "tree": list(tree),
                              "candidate_budget": budget,
                              "vocab": vocab,
                              "config": "MIXTRAL_8X7B.reduced(d_model=64)"
                                        " / max_batch=2 x2"}}
    for name, kw in (("chain", dict(n_cand=budget)),
                     ("tree", dict(spec_tree=tuple(tree)))):
        rows: list = []
        out = run(rows, requests, gen, rate, seed, prefix=f"spec_{name}",
                  vocab=vocab, **kw)
        s = _summary(out)
        s["acceptance"] = _accept_per_pass(out["engine"], name)
        report[name] = s
    ch = report["chain"]["acceptance"]
    tr = report["tree"]["acceptance"]
    report["verdict"] = {
        "chain_accepted_per_pass": ch["accepted_per_pass"],
        "tree_accepted_per_pass": tr["accepted_per_pass"],
        "accepted_per_pass_ratio": tr["accepted_per_pass"]
        / max(ch["accepted_per_pass"], 1e-9),
        "tok_per_s_ratio": report["tree"]["tok_per_s"]
        / max(report["chain"]["tok_per_s"], 1e-9),
        "waste_frac_chain": ch["waste_frac"],
        "waste_frac_tree": tr["waste_frac"],
    }
    return report


def _async_engine(clock: str, spec=None):
    """Reduced engine with the QoS knobs both async-A/B legs share."""
    from repro.configs.base import MIXTRAL_8X7B, MISTRAL_7B
    from repro.serving.engine import SchedulerConfig, ServingEngine

    tcfg = MIXTRAL_8X7B.reduced(d_model=64)
    dcfg = MISTRAL_7B.reduced(d_model=32, vocab=tcfg.vocab_size)
    # explicit max_len: the open-loop leg sizes caches at the *first*
    # arrival, so capacity must already cover the trace's longest
    # prompt (the closed-loop leg sees the whole queue up front)
    eng = ServingEngine(tcfg, dcfg, config=SchedulerConfig(
        max_batch=2, n_cand=2, length_bucket=16, max_len=160,
        clock=clock, qos=True,
        tenant_weights={"acme": 2.0, "beta": 1.0},
        preempt=True, preempt_min_remaining=2))
    return eng, tcfg


TENANTS = {"acme": {"share": 2.0, "priority": 1},
           "beta": {"share": 1.0, "priority": 0}}


def _tenant_trace(requests: int, gen: int, rate: float, seed: int,
                  vocab: int) -> list:
    from repro.serving.trace import tenant_poisson_requests

    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(48, 81)) if rng.random() < 0.25
            else int(rng.integers(8, 17)) for _ in range(requests)]
    prompts = [rng.integers(0, vocab, L).astype(np.int32) for L in lens]
    gens = rng.integers(max(2, gen // 2), gen + 1, requests)
    return tenant_poisson_requests(prompts, gens.tolist(), rate,
                                   TENANTS, seed)


def _tenant_ttft(handles: list) -> dict:
    from repro.serving.engine import latency_percentiles

    out: dict = {}
    for t in sorted({r.tenant for r in handles}):
        rs = [r for r in handles if r.tenant == t]
        out[t] = {"requests": len(rs),
                  "ttft_s": latency_percentiles(rs, "ttft_s"),
                  "e2e_s": latency_percentiles(rs, "latency_s")}
    return out


def async_compare(requests: int = 10, gen: int = 8, rate: float = 2.0,
                  seed: int = 0, speed: float = 8.0,
                  max_queue: int = 6) -> dict:
    """Open-loop asyncio leg vs the closed-loop ``run()`` path on the
    same two-tenant Poisson trace -> ``BENCH_serving_async.json``.

    The async leg streams token-by-token through
    :class:`repro.serving.server.AsyncServingServer` with a bounded
    admission queue (backpressure), weighted tenant fairness and
    priority preemption; ``speed`` compresses the arrival gaps so the
    CPU-reduced decode — not the trace clock — is the bottleneck.
    Streams must match the closed-loop results token for token
    (per-sequence losslessness), and the digest records per-tenant TTFT
    percentiles plus the throughput ratio between the legs.
    """
    import asyncio

    from repro.serving.server import AsyncServingServer
    from repro.serving.trace import replay_open_loop

    # ---- closed-loop leg: virtual clock, same trace -----------------
    eng, tcfg = _async_engine("virtual")
    eng.init_from_seed(seed)
    closed_reqs = _tenant_trace(requests, gen, rate, seed,
                                tcfg.vocab_size)
    for r in closed_reqs:
        eng.submit(r)
    closed_done = eng.run()
    closed_tps = eng.throughput(closed_done)
    closed_stats = eng.stats()

    # ---- open-loop async leg: real clock, same trace ----------------
    aeng, _ = _async_engine("real")
    aeng.init_from_seed(seed)
    trace = _tenant_trace(requests, gen, rate, seed, tcfg.vocab_size)

    async def _drive():
        async with AsyncServingServer(aeng, max_queue=max_queue) as srv:
            return await replay_open_loop(srv, trace, speed=speed)

    tokens, handles = asyncio.run(_drive())
    async_stats = aeng.stats()
    async_tps = aeng.throughput(handles)

    closed_by_rid = {r.rid: list(map(int, r.result)) for r in closed_done}
    parity = all(tokens.get(rid) == toks
                 for rid, toks in closed_by_rid.items())
    report = {
        "trace": {"requests": requests, "gen": gen, "rate_rps": rate,
                  "seed": seed, "speed": speed, "max_queue": max_queue,
                  "tenants": TENANTS,
                  "config": "MIXTRAL_8X7B.reduced(d_model=64) / "
                            "max_batch=2 x2, n_cand=2, qos+preempt"},
        "closed_loop": {"tok_per_s": closed_tps,
                        "rounds": closed_stats["rounds"],
                        "occupancy": closed_stats["mean_occupancy"],
                        "fused_compiles": closed_stats["fused_compiles"],
                        "per_tenant": _tenant_ttft(closed_done)},
        "async_open_loop": {"tok_per_s": async_tps,
                            "rounds": async_stats["rounds"],
                            "occupancy": async_stats["mean_occupancy"],
                            "fused_compiles":
                                async_stats["fused_compiles"],
                            "rejected": async_stats["rejected"],
                            "preempted": async_stats["preempted"],
                            "streamed": sum(1 for v in tokens.values()
                                            if v is not None),
                            "drained": not aeng.has_work(),
                            "per_tenant": _tenant_ttft(handles)},
        "verdict": {"stream_parity_with_closed_loop": parity,
                    "tok_per_s_ratio_async_over_closed":
                        async_tps / max(closed_tps, 1e-9)},
    }
    return report


def obs_run(requests: int = 10, gen: int = 8, rate: float = 2.0,
            seed: int = 0, trace_out: str | None = None,
            metrics_out: str | None = None) -> dict:
    """Observability benchmark: the same Poisson trace twice — once with
    the span tracer on (utilization / bubble accounting, Perfetto trace,
    metrics snapshot) and once with tracing disabled (throughput parity
    + fused-compile baseline).  Returns the ``BENCH_serving_obs.json``
    digest; writes the raw trace/metrics JSON when paths are given.
    """
    import json

    from repro.obs import timelines_summary

    rows: list = []
    traced = run(rows, requests, gen, rate, seed, prefix="obs",
                 trace=True, request_timeline=True)
    eng = traced["engine"]
    rep = eng.metrics()
    util = rep["utilization"]
    if trace_out:
        with open(trace_out, "w") as f:
            json.dump(eng.chrome_trace(), f)
    if metrics_out:
        with open(metrics_out, "w") as f:
            json.dump(rep, f, indent=2)

    # parity leg: tracing off must keep the fused step at one compile and
    # throughput within noise of the paged baseline
    rows2: list = []
    plain = run(rows2, requests, gen, rate, seed, prefix="plain")
    snap = rep["metrics"]
    digest = {
        "trace": {"requests": requests, "gen": gen, "rate_rps": rate,
                  "seed": seed,
                  "config": "MIXTRAL_8X7B.reduced(d_model=64) / "
                            "max_batch=2 x2, n_cand=2"},
        "utilization": {
            "rounds": util["rounds"],
            "gpu_busy_frac": util["gpu_busy_frac"],
            "mean_round_busy_frac": util["mean_round_busy_frac"],
            "busy_s": util["busy_s"],
            "stall_s": util["stall_s"],
            "idle_s": util["idle_s"],
            "per_round_busy_frac": [r["busy_frac"]
                                    for r in util["per_round"]],
            "per_round_stall_s": [r["stall_s"]
                                  for r in util["per_round"]],
        },
        "transfers": {
            "bytes_by_tier": snap["counters"].get(
                "transfer_bytes_total", {}),
            "seconds_by_tier": snap["counters"].get(
                "transfer_seconds_total", {}),
        },
        "acceptance_hist": snap["histograms"].get(
            "spec_accepted_tokens", {}),
        "kv_gauges": {k: v for k, v in snap["gauges"].items()
                      if k.startswith("kv_")},
        "pipeline_traces": snap["counters"].get(
            "pipeline_traces_total", {}),
        "traced_tok_per_s": traced["stats"]["tok_per_s"],
        "untraced_tok_per_s": plain["stats"]["tok_per_s"],
        "untraced_fused_compiles": plain["stats"]["fused_compiles"],
        "trace_events": len(eng.chrome_trace()["traceEvents"]),
        # request-level latency percentiles + per-request timeline
        # aggregate (the bench_compare regression gate keys on these)
        "ttft": traced["ttft"],
        "e2e": traced["e2e"],
        "request_timelines": timelines_summary(eng.request_timelines()),
    }
    return digest


def main():
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--async", dest="run_async", action="store_true",
                    help="open-loop asyncio serving leg (2 tenants, "
                         "bounded queue, preemption) vs the closed-loop "
                         "run() path on the same trace")
    ap.add_argument("--speed", type=float, default=8.0,
                    help="arrival-gap compression for the async leg")
    ap.add_argument("--async-out", default="BENCH_serving_async.json",
                    help="JSON report path for --async")
    ap.add_argument("--compare", action="store_true",
                    help="contiguous vs paged A/B on one fixed trace")
    ap.add_argument("--out", default="BENCH_serving_paged.json",
                    help="JSON report path for --compare")
    ap.add_argument("--compare-spec", action="store_true",
                    help="chain vs tree speculation A/B on one fixed "
                         "trace at equal candidate budget")
    ap.add_argument("--spec-tree", default="3,2",
                    help="tree branching per depth for --compare-spec")
    ap.add_argument("--spec-out", default="BENCH_serving_tree.json",
                    help="JSON report path for --compare-spec")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace JSON "
                         "(enables the observability run)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics snapshot + utilization "
                         "report JSON (enables the observability run)")
    ap.add_argument("--obs-out", default="BENCH_serving_obs.json",
                    help="utilization digest path for the obs run")
    args = ap.parse_args()
    if args.run_async:
        report = async_compare(args.requests, args.gen, args.rate,
                               speed=args.speed)
        with open(args.async_out, "w") as f:
            json.dump(report, f, indent=2)
        v = report["verdict"]
        a = report["async_open_loop"]
        print(f"wrote {args.async_out}")
        print(f"stream parity with closed loop: "
              f"{v['stream_parity_with_closed_loop']}; drained: "
              f"{a['drained']}; rejected {a['rejected']}, "
              f"preempted {a['preempted']}")
        print(f"tok/s async/closed: "
              f"{v['tok_per_s_ratio_async_over_closed']:.2f}x "
              f"({a['tok_per_s']:.2f} vs "
              f"{report['closed_loop']['tok_per_s']:.2f})")
        for t, d in a["per_tenant"].items():
            print(f"  tenant {t}: {d['requests']} reqs, ttft p50 "
                  f"{d['ttft_s']['p50']:.3f}s p95 "
                  f"{d['ttft_s']['p95']:.3f}s")
        return
    if args.trace_out or args.metrics_out:
        digest = obs_run(args.requests, args.gen, args.rate,
                         trace_out=args.trace_out,
                         metrics_out=args.metrics_out)
        with open(args.obs_out, "w") as f:
            json.dump(digest, f, indent=2)
        u = digest["utilization"]
        print(f"wrote {args.obs_out}"
              + (f", {args.trace_out}" if args.trace_out else "")
              + (f", {args.metrics_out}" if args.metrics_out else ""))
        print(f"GPU busy fraction: {u['gpu_busy_frac']:.2f} over "
              f"{u['rounds']} rounds "
              f"(stall {u['stall_s']:.2f}s, idle {u['idle_s']:.2f}s)")
        print(f"tok/s traced {digest['traced_tok_per_s']:.2f} vs "
              f"untraced {digest['untraced_tok_per_s']:.2f}; "
              f"fused compiles (untraced) "
              f"{digest['untraced_fused_compiles']}")
        return
    if args.compare_spec:
        tree = tuple(int(k) for k in args.spec_tree.split(","))
        report = compare_spec(args.requests, args.gen, args.rate,
                              tree=tree)
        with open(args.spec_out, "w") as f:
            json.dump(report, f, indent=2)
        v = report["verdict"]
        print(f"wrote {args.spec_out}")
        print(f"accepted candidates per target pass: "
              f"chain {v['chain_accepted_per_pass']:.3f} vs "
              f"tree {v['tree_accepted_per_pass']:.3f} "
              f"({v['accepted_per_pass_ratio']:.2f}x)")
        print(f"tokens/s ratio (tree/chain): {v['tok_per_s_ratio']:.2f}x")
        return
    if args.compare:
        report = compare(args.requests, args.gen, args.rate)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        v = report["verdict"]
        print(f"wrote {args.out}")
        print(f"peak KV reduction (paged):      "
              f"{100 * v['peak_kv_reduction']:.1f}%")
        print(f"peak KV reduction (paged+int8): "
              f"{100 * v['int8_peak_kv_reduction']:.1f}%")
        print(f"tokens/s ratio (paged/contig):  "
              f"{v['tok_per_s_ratio']:.2f}x")
        return
    rows: list = []
    out = run(rows, args.requests, args.gen, args.rate)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")
    st = out["stats"]
    print(f"\n{len(out['done'])} requests, {st['rounds']} rounds, "
          f"occupancy {st['mean_occupancy']:.2f}, "
          f"{st['fused_compiles']} fused compile(s)")


if __name__ == "__main__":
    main()
