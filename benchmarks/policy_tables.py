"""Tables 5-10: the paper's policy-impact grids, reproduced with the
ParaSpec planner and scored by Spearman rank correlation — the planner's
job is to *rank* policies correctly, so ranking fidelity is the metric
(absolute tok/s on HumanEval-length prompts is sensitive to the CPU
constants calibrated on SummEval).
"""
from __future__ import annotations

import numpy as np
from scipy import stats

from repro.configs.base import MISTRAL_7B, MIXTRAL_8X7B, MIXTRAL_8X22B
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.data.pipeline import DATASET_STATS
from repro.sim.hardware import ENV1, ENV2

# (bs_prefill, bs_decode, bs_draft, n_cand) -> paper tok/s
TABLE5_8X7B_HUMANEVAL = {  # Table 5 (subset spanning the grid)
    (80, 160, 6, 1): 15.869, (80, 160, 6, 2): 20.964, (80, 160, 6, 4): 28.914,
    (80, 160, 6, 6): 33.711, (80, 160, 6, 8): 33.690,
    (80, 200, 8, 1): 18.828, (80, 200, 8, 4): 30.452, (80, 200, 8, 8): 31.884,
    (80, 256, 8, 2): 27.123, (80, 256, 8, 6): 33.622,
    (80, 256, 10, 6): 34.665,
}

TABLE7_8X7B_SUMMEVAL = {  # Table 7 (subset incl. the bs=320 collapse)
    (50, 128, 5, 3): 19.735, (50, 256, 5, 2): 15.624,
    (80, 128, 5, 1): 11.682, (80, 128, 5, 4): 19.464, (80, 128, 5, 8): 21.531,
    (80, 192, 5, 2): 16.830, (80, 192, 5, 8): 22.712,
    (80, 192, 8, 8): 24.732,
    (80, 256, 5, 4): 20.441, (80, 320, 5, 1): 4.444, (80, 320, 8, 2): 6.074,
}

TABLE10_8X22B_SUMMEVAL = {  # Table 10
    (16, 32, 6, 4): 3.711, (16, 32, 6, 6): 3.486, (16, 32, 8, 8): 3.975,
    (16, 64, 6, 4): 4.579, (16, 64, 6, 6): 5.141, (16, 64, 8, 8): 5.911,
}


def _ours(target, draft, hw, dataset, table, overload_bs=320):
    wl = Workload(int(DATASET_STATS[dataset]["s_avg"]), 48, 0.75)
    pl = ParaSpecPlanner(target, draft, hw)
    ours, paper = [], []
    for pol, ref in table.items():
        rep = pl.evaluate(Policy(*pol), wl)
        thr = rep.throughput
        # the paper's bs>=320 rows collapse from memory/CPU overload; the
        # planner flags them infeasible — score them as near-zero
        if pol[1] >= overload_bs and not rep.feasible:
            thr = 0.1
        ours.append(thr)
        paper.append(ref)
    return np.array(ours), np.array(paper)


def run(rows: list):
    for name, (tgt, hw, ds, table) in {
        "table5_8x7b_humaneval": (MIXTRAL_8X7B, ENV1, "humaneval",
                                  TABLE5_8X7B_HUMANEVAL),
        "table7_8x7b_summeval": (MIXTRAL_8X7B, ENV1, "summeval",
                                 TABLE7_8X7B_SUMMEVAL),
        "table10_8x22b_summeval": (MIXTRAL_8X22B, ENV2, "summeval",
                                   TABLE10_8X22B_SUMMEVAL),
    }.items():
        ours, paper = _ours(tgt, MISTRAL_7B, hw, ds, table)
        rho = stats.spearmanr(ours, paper).statistic
        rows.append((f"{name}_spearman_rank_corr", float(rho),
                     f"{len(paper)} policies; 1.0 = identical ranking"))
        # relative throughput of the best-vs-worst policy should match
        spread_ours = ours.max() / max(ours.min(), 1e-9)
        spread_paper = paper.max() / paper.min()
        rows.append((f"{name}_best_worst_spread", float(spread_ours),
                     f"paper={spread_paper:.2f}x"))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.4f},{r[2]}")
