"""Run every benchmark; one function per paper table/figure.

Prints ``name,value,derived`` CSV (per the repo scaffold convention) and
the roofline tables.  ``python -m benchmarks.run [--skip-microbench]``.
"""
from __future__ import annotations

import sys


def main() -> None:
    rows: list = []

    from benchmarks import paper_figures, policy_tables
    paper_figures.run(rows)
    policy_tables.run(rows)

    if "--skip-microbench" not in sys.argv:
        from benchmarks import microbench
        microbench.run(rows)

    if "--serving" in sys.argv:
        # Poisson-trace continuous-batching benchmark (compiles the real
        # reduced-scale engine — seconds, not milliseconds; opt-in)
        from benchmarks import serving_bench
        serving_bench.run(rows)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")

    print()
    from benchmarks import roofline
    for mesh in ("single", "multi"):
        rows_r = roofline.print_table(mesh)
        n_ok = sum(1 for r in rows_r if r["dominant"] != "SKIP")
        n_fit = sum(1 for r in rows_r
                    if r["dominant"] != "SKIP" and r["fits_16gib_tpu_est"])
        print(f"-> {n_ok} compiled, {n_fit} fit 16 GiB/chip, "
              f"{len(rows_r) - n_ok} skipped (long-context policy)\n")


if __name__ == "__main__":
    main()
