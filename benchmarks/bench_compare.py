"""Benchmark regression gate: compare a fresh ``serving_bench`` obs
digest against the committed ``BENCH_serving_obs.json`` baseline.

CI runs the obs benchmark on every push; this gate turns its digest
into a pass/fail signal with explicit, documented tolerances instead of
an eyeballed JSON diff:

* ``tok_per_s`` (traced + untraced) must stay above
  ``tol_throughput`` x baseline (default 0.35 — shared CI runners are
  noisy; the gate catches collapses, not jitter).
* ``gpu_busy_frac`` (the paper's utilization metric, derived from the
  span tracer's bubble accounting) must stay above ``tol_busy`` x
  baseline (default 0.5).
* TTFT p50/p95 must stay below ``tol_latency`` x baseline (default
  3.0).
* ``untraced_fused_compiles`` must not exceed the baseline: a second
  fused-step compile is a hard architectural regression (shape leak),
  never hardware noise — no tolerance.

Override knob: ``--override`` (or ``BENCH_COMPARE_OVERRIDE=1`` in the
environment) downgrades a failure to a warning + zero exit, for
intentional baseline-moving changes — refresh the committed baseline in
the same PR.

    PYTHONPATH=src python -m benchmarks.bench_compare \\
        --baseline BENCH_serving_obs.json --current /tmp/obs_digest.json
    # regenerate the current digest inline (same params as the baseline)
    PYTHONPATH=src python -m benchmarks.bench_compare --run
"""
from __future__ import annotations

import json
import os

#: (check name, digest path, kind, default tolerance).  Kinds:
#: ``min_ratio`` — current >= tol * baseline;
#: ``max_ratio`` — current <= tol * baseline;
#: ``max_value`` — current <= baseline (tol unused; exactness gates).
CHECKS = (
    ("untraced_tok_per_s", ("untraced_tok_per_s",), "min_ratio",
     "tol_throughput"),
    ("traced_tok_per_s", ("traced_tok_per_s",), "min_ratio",
     "tol_throughput"),
    ("gpu_busy_frac", ("utilization", "gpu_busy_frac"), "min_ratio",
     "tol_busy"),
    ("ttft_p50_s", ("ttft", "p50"), "max_ratio", "tol_latency"),
    ("ttft_p95_s", ("ttft", "p95"), "max_ratio", "tol_latency"),
    ("fused_compiles", ("untraced_fused_compiles",), "max_value", None),
)

DEFAULT_TOLERANCES = {"tol_throughput": 0.35, "tol_busy": 0.5,
                      "tol_latency": 3.0}


def _lookup(digest: dict, path: tuple):
    cur = digest
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    try:
        return float(cur)
    except (TypeError, ValueError):
        return None


def compare_digests(baseline: dict, current: dict,
                    tolerances: dict | None = None) -> dict:
    """Evaluate every check; returns ``{"ok", "checks": [...]}``.

    A metric missing from the *baseline* is skipped (legacy baseline —
    refresh it); missing from the *current* digest it fails (the bench
    stopped producing it, which is itself a regression).
    """
    tol = dict(DEFAULT_TOLERANCES)
    tol.update(tolerances or {})
    checks, ok = [], True
    for name, path, kind, tol_key in CHECKS:
        base = _lookup(baseline, path)
        cur = _lookup(current, path)
        entry = {"name": name, "kind": kind, "baseline": base,
                 "current": cur,
                 "tolerance": tol[tol_key] if tol_key else None}
        if base is None or base != base:
            entry["ok"], entry["note"] = True, "skipped: not in baseline"
        elif cur is None or cur != cur:
            entry["ok"], entry["note"] = False, "missing from current"
        elif kind == "min_ratio":
            limit = tol[tol_key] * base
            entry["limit"] = limit
            entry["ok"] = cur >= limit
        elif kind == "max_ratio":
            limit = tol[tol_key] * base
            entry["limit"] = limit
            entry["ok"] = cur <= limit
        else:                                    # max_value: exactness
            entry["limit"] = base
            entry["ok"] = cur <= base
        ok = ok and entry["ok"]
        checks.append(entry)
    return {"ok": ok, "checks": checks}


def _fmt(v) -> str:
    return "-" if v is None else f"{v:.4g}"


def print_report(report: dict):
    for c in report["checks"]:
        mark = "ok  " if c["ok"] else "FAIL"
        note = f"  ({c['note']})" if c.get("note") else ""
        print(f"  [{mark}] {c['name']:<22} current={_fmt(c['current'])}"
              f"  baseline={_fmt(c['baseline'])}"
              f"  limit={_fmt(c.get('limit'))}{note}")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_serving_obs.json",
                    help="committed digest to gate against")
    ap.add_argument("--current", default="/tmp/obs_digest.json",
                    help="fresh digest to evaluate")
    ap.add_argument("--run", action="store_true",
                    help="regenerate --current inline via "
                         "serving_bench.obs_run (default bench params)")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--tol-throughput", type=float,
                    default=DEFAULT_TOLERANCES["tol_throughput"],
                    help="min tok/s ratio vs baseline")
    ap.add_argument("--tol-busy", type=float,
                    default=DEFAULT_TOLERANCES["tol_busy"],
                    help="min GPU-busy-fraction ratio vs baseline")
    ap.add_argument("--tol-latency", type=float,
                    default=DEFAULT_TOLERANCES["tol_latency"],
                    help="max TTFT ratio vs baseline")
    ap.add_argument("--override", action="store_true",
                    help="report failures but exit 0 (baseline-moving "
                         "change; refresh the baseline in the same PR). "
                         "BENCH_COMPARE_OVERRIDE=1 does the same")
    args = ap.parse_args()

    if args.run:
        from benchmarks.serving_bench import obs_run
        current = obs_run(args.requests, args.gen)
        with open(args.current, "w") as f:
            json.dump(current, f, indent=2)
    else:
        with open(args.current) as f:
            current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    report = compare_digests(baseline, current,
                             {"tol_throughput": args.tol_throughput,
                              "tol_busy": args.tol_busy,
                              "tol_latency": args.tol_latency})
    print(f"bench_compare: {args.current} vs {args.baseline}")
    print_report(report)
    override = args.override or bool(os.environ.get(
        "BENCH_COMPARE_OVERRIDE"))
    if report["ok"]:
        print("bench_compare: PASS")
    elif override:
        print("bench_compare: FAIL (overridden — refresh the committed "
              "baseline in this PR)")
    else:
        print("bench_compare: FAIL")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
