"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch x input-shape x mesh) record produced by
``repro.launch.dryrun`` this derives the three roofline terms on TPU v5e:

    compute   = FLOPs / (chips * 197e12)
    memory    = bytes / (chips * 819e9)
    collective= collective_bytes / (chips * 50e9)

Methodology notes (also in EXPERIMENTS.md §Roofline):
* XLA's ``cost_analysis()`` counts each while-loop body ONCE, so HLO FLOPs/
  bytes under-count scanned layers.  The primary terms therefore use
  *analytic* per-step FLOPs/bytes (6·N·D train / 2·N_active·D serve, plus
  KV traffic), with the HLO numbers reported as cross-checks and the ratio
  MODEL_FLOPS/HLO_FLOPs listed per the brief.
* Collective bytes from the HLO parse are likewise body-once; the analytic
  model (FSDP weight gathers + TP reductions + MoE all-to-all) is the
  primary number and the parse the cross-check.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, get_config
from repro.configs.base import INPUT_SHAPES, ModelConfig

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2 ** 30


# ---------------------------------------------------------------------------
# analytic per-step work model


def model_flops(cfg: ModelConfig, shape) -> float:
    """Global model FLOPs for one step: 6·N·D (train) / 2·N_active·D."""
    n = cfg.active_param_count()
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n * tokens
        # quadratic attention term
        if not cfg.attention_free:
            att = 0
            for k in cfg.layer_pattern:
                if k == "attn":
                    att += shape.seq_len
                elif k == "swa":
                    att += min(cfg.sliding_window, shape.seq_len)
            att *= cfg.n_groups
            flops += (2.0 * 2 * shape.global_batch * shape.seq_len
                      * cfg.n_heads * cfg.head_dim * att / cfg.n_layers
                      * cfg.n_layers) / cfg.n_layers * 1.0 if False else 0
            flops += 4.0 * shape.global_batch * shape.seq_len * \
                cfg.n_heads * cfg.head_dim * _avg_ctx(cfg, shape) * \
                cfg.n_layers / 2
        return flops
    # decode: one token per sequence
    tokens = shape.global_batch
    flops = 2.0 * n * tokens
    if not cfg.attention_free:
        flops += 4.0 * tokens * cfg.n_heads * cfg.head_dim * \
            _avg_ctx(cfg, shape) * cfg.n_layers
    return flops


def _avg_ctx(cfg: ModelConfig, shape) -> float:
    """Average attended context per layer (window-aware)."""
    ctx = 0
    n_att = 0
    for k in cfg.layer_pattern:
        if k == "attn":
            ctx += shape.seq_len
            n_att += 1
        elif k == "swa":
            ctx += min(cfg.sliding_window, shape.seq_len)
            n_att += 1
    return ctx / max(n_att, 1)


def model_bytes(cfg: ModelConfig, shape) -> float:
    """Global HBM traffic for one step (weights + KV + activations)."""
    p = cfg.param_bytes()
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        act = tokens * cfg.d_model * 2 * cfg.n_layers * 2  # fwd+bwd, bf16
        return 4 * p + act          # read W (fwd+bwd), write/read grads
    kv = kv_cache_bytes(cfg, shape)
    if shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        act = tokens * cfg.d_model * 2 * cfg.n_layers
        return cfg.active_param_count() * 2 + kv + act
    # decode: read all active weights + read the whole KV + write one row
    return cfg.active_param_count() * 2 + kv


def kv_cache_bytes(cfg: ModelConfig, shape) -> float:
    tot = 0.0
    for k in cfg.layer_pattern:
        if k == "attn":
            slots = shape.seq_len
        elif k == "swa":
            slots = min(cfg.sliding_window, shape.seq_len)
        elif k == "rglru":
            tot += cfg.n_groups * shape.global_batch * cfg.rnn_width * 4
            continue
        else:  # rwkv
            hd = cfg.rwkv_head_size
            tot += cfg.n_groups * shape.global_batch * \
                (cfg.d_model // hd) * hd * hd * 4
            continue
        tot += cfg.n_groups * 2 * shape.global_batch * slots * \
            cfg.n_kv_heads * cfg.head_dim * 2
    return tot


def analytic_collective_bytes(cfg: ModelConfig, shape, n_chips: int,
                              model_size: int = 16) -> float:
    """Per-chip collective traffic per step, from the sharding design of
    DESIGN.md §6 *after* the §Perf optimizations.

    train/prefill: FSDP weight gathers per traversal + grad reduce-scatter
    + TP all-reduce of layer outputs + MoE all-to-all.
    decode: weight-stationary — the weights never move; traffic is the
    replicated token block's psums (qkv + FFN partials + expert combine).
    """
    p_shard = cfg.param_bytes() / n_chips
    if shape.phase == "decode":
        b = shape.global_batch
        # per layer: psum of (B, D) x2 (attn out + FFN out) in f32, plus the
        # token-block reshard, plus the MoE expert-combine psum
        total = cfg.n_layers * 2 * b * cfg.d_model * 4
        if cfg.is_moe:
            total += cfg.n_moe_layers * (
                2 * b * cfg.d_ff * 4            # pre-activation partials
                + cfg.n_experts * b * cfg.d_model / model_size * 4)
        return total

    gather = cfg.param_bytes() / model_size  # per chip per traversal
    passes = 3 if shape.phase == "train" else 1
    total = gather * passes
    if shape.phase == "train":
        total += p_shard * 2        # grad reduce-scatter + opt sync
    tokens_local = shape.global_batch * shape.seq_len / \
        max(n_chips / model_size, 1)
    total += 2 * tokens_local * cfg.d_model * 2 * cfg.n_layers * \
        (2 if shape.phase == "train" else 1)
    # MoE all-to-all: dispatch+return of local token buffers
    if cfg.is_moe:
        toks = shape.global_batch * shape.seq_len
        total += 2 * 2 * toks * cfg.d_model * 2 * cfg.n_moe_layers / n_chips
    return total


# ---------------------------------------------------------------------------


def load_records(mesh: str = "single") -> list:
    out = []
    d = RESULTS / mesh
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    model_size = 16

    mf = model_flops(cfg, shape)
    mb = model_bytes(cfg, shape)
    coll = analytic_collective_bytes(cfg, shape, chips, model_size)

    t_compute = mf / (chips * PEAK_FLOPS)
    t_memory = mb / (chips * HBM_BW)
    t_coll = coll / ICI_BW          # already per-chip
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    hlo_flops = rec.get("cost", {}).get("flops", 0.0)
    hlo_bytes = rec.get("cost", {}).get("bytes accessed", 0.0)
    parsed_coll = rec.get("collectives", {}).get("total_bytes", 0)

    # CPU-backend artifact: XLA-CPU emulates every bf16 dot by converting
    # both operands to f32; the converts of loop-invariant weights are
    # hoisted, materializing a full f32 copy of the parameters (verified
    # in EXPERIMENTS.md §Dry-run).  A real TPU has native bf16 MXU input —
    # no such copy.  Corrected estimate subtracts 2x the bf16 weight bytes.
    artifact = 0.0
    if cfg.dtype == "bfloat16":
        artifact = 2.0 * cfg.param_bytes() / chips
    tpu_gib = rec["per_device_gib"] - artifact / 2 ** 30

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "phase": rec["phase"], "chips": chips,
        "per_device_gib": rec["per_device_gib"],
        "tpu_est_gib": tpu_gib,
        "fits_16gib_tpu_est": bool(tpu_gib <= 16.0),
        "fits_16gib": rec["fits_16gib"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_per_dev_bodyonce": hlo_flops,
        "model_hlo_flop_ratio": (mf / chips) / hlo_flops if hlo_flops else
        float("nan"),
        "hlo_bytes_per_dev_bodyonce": hlo_bytes,
        "parsed_collective_gib_bodyonce": parsed_coll / 2 ** 30,
        "compile_s": rec.get("compile_s", 0.0),
    }


def full_table(mesh: str = "single") -> list:
    rows = []
    for rec in load_records(mesh):
        r = roofline_row(rec)
        if r:
            rows.append(r)
        elif rec.get("status") == "skip":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "dominant": "SKIP",
                         "skip_reason": rec.get("reason", "")})
    return rows


def print_table(mesh: str = "single"):
    rows = full_table(mesh)
    print(f"# Roofline — {mesh}-pod mesh "
          f"({256 if mesh == 'single' else 512} chips of TPU v5e)")
    hdr = (f"{'arch':28s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'bound':>10s} {'GiB raw':>8s} "
           f"{'GiB tpu':>8s} {'fits':>5s}")
    print(hdr)
    for r in rows:
        if r["dominant"] == "SKIP":
            print(f"{r['arch']:28s} {r['shape']:12s} {'—':>9s} {'—':>9s} "
                  f"{'—':>9s} {'SKIP':>10s}")
            continue
        print(f"{r['arch']:28s} {r['shape']:12s} "
              f"{r['t_compute_s']*1e3:9.2f} {r['t_memory_s']*1e3:9.2f} "
              f"{r['t_collective_s']*1e3:9.2f} {r['dominant']:>10s} "
              f"{r['per_device_gib']:8.2f} {r['tpu_est_gib']:8.2f} "
              f"{'yes' if r['fits_16gib_tpu_est'] else 'NO':>5s}")
    return rows


if __name__ == "__main__":
    print_table("single")
    print()
    print_table("multi")
