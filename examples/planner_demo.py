"""ParaSpec Planner demo: reproduce the paper's policy search (§4.3).

    PYTHONPATH=src python examples/planner_demo.py

Evaluates the paper's published policies for Mixtral-8x7B on Env#1 and
shows the planner's own search finding a comparable-or-better one, plus
the Fig 2 "marginal utility of GPU memory" sweep.
"""
from repro.configs.base import MISTRAL_7B, MIXTRAL_8X7B
from repro.core.placement import hbm_pinned_fraction, plan_placement
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.sim.hardware import ENV1
from repro.sim.simulator import memory_sweep

wl = Workload(prompt_len=503, gen_len=48, accept_prob=0.75)  # SummEval
planner = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)

print("paper policies (Table 7):")
for pol in [Policy(80, 192, 8, 8), Policy(80, 128, 5, 8),
            Policy(50, 256, 5, 2), Policy(80, 320, 8, 8)]:
    rep = planner.evaluate(pol, wl)
    print(f"  {pol.astuple()}: {rep.throughput:6.2f} tok/s "
          f"(E[n]={rep.expected_tokens:.2f}, "
          f"round={rep.detail['t_round']:.1f}s, "
          f"{'feasible' if rep.feasible else 'INFEASIBLE'})")

best = planner.search(wl)
print(f"\nplanner search -> {best.policy.astuple()} "
      f"= {best.throughput:.2f} tok/s (paper best 24.7 @ (80,192,8,8))")

plan = plan_placement(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
print(f"\nplacement: hbm={plan.hbm_used/2**30:.1f}G "
      f"host={plan.host_used/2**30:.1f}G disk={plan.disk_used/2**30:.1f}G "
      f"pinned-target-fraction={hbm_pinned_fraction(plan):.2f}")
print("  (the draft model occupies the 'low-yield' HBM; Fig 2 shows why)")

print("\nFig 2 sweep (GPU memory -> FlexGen-style throughput):")
for row in memory_sweep(MIXTRAL_8X7B, ENV1, wl, [0.9, 0.5, 0.25, 0.166]):
    print(f"  {row['mem_gib']:5.1f} GiB pinned={row['pinned_frac']*100:4.1f}%"
          f" -> {row['throughput']:.2f} tok/s")
print("  => throughput barely moves: GPU memory has marginal utility, so "
      "give it to the draft model instead.")
