"""Quickstart: speculative decoding with SpecOffload in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a tiny target + draft pair, prefills a prompt batch, and runs
draft-then-verify rounds — printing per-round acceptance so you can watch
speculative decoding emit 1..n_cand+1 tokens per target pass.  The output
stream is verified to exactly equal the target's own greedy decoding
(speculative decoding is lossless).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.spec_decode import spec_round
from repro.models import model as M
from repro.models.transformer import init_cache

target_cfg = ModelConfig(name="target", arch_type="dense", n_layers=4,
                         d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                         vocab_size=211, dtype="float32", remat=False)
draft_cfg = ModelConfig(name="draft", arch_type="dense", n_layers=2,
                        d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
                        vocab_size=211, dtype="float32", remat=False)

tp = M.init_params(target_cfg, jax.random.PRNGKey(0))
dp = M.init_params(draft_cfg, jax.random.PRNGKey(1))

B, L, GEN, N_CAND = 4, 16, 24, 4
prompts = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, 211)

prefill = jax.jit(M.prefill, static_argnums=(1,))
round_fn = jax.jit(spec_round, static_argnames=(
    "target_cfg", "draft_cfg", "n_cand", "mesh", "sample"))

tc = init_cache(target_cfg, B, 128)
dc = init_cache(draft_cfg, B, 128)
logits, tc = prefill(tp, target_cfg, prompts, tc)
_, dc = prefill(dp, draft_cfg, prompts, dc)
t_next = jnp.argmax(logits, -1)

out = [[int(t_next[b])] for b in range(B)]
rounds = 0
while min(len(o) for o in out) < GEN:
    r = round_fn(tp, target_cfg, tc, dp, draft_cfg, dc, t_next, N_CAND)
    tc, dc, t_next = r["target_cache"], r["draft_cache"], r["t_next"]
    acc = np.asarray(r["n_accept"])
    print(f"round {rounds:2d}: accepted per seq = {acc.tolist()} "
          f"(+1 bonus each)")
    for b in range(B):
        for i in range(int(r["n_emitted"][b])):
            out[b].append(int(r["tokens"][b, i]))
    rounds += 1

total = sum(min(len(o), GEN) for o in out)
print(f"\n{total} tokens in {rounds} verify rounds "
      f"({total/B/rounds:.2f} tokens/seq/round vs 1.0 for plain decoding)")

# losslessness check vs the target's own greedy decoding
cache = init_cache(target_cfg, B, 128)
lg, cache = prefill(tp, target_cfg, prompts, cache)
decode = jax.jit(M.decode_step, static_argnums=(1,))
tok = jnp.argmax(lg, -1)
for t in range(GEN):
    ref_tok = int(tok[0])
    assert out[0][t] == ref_tok, (t, out[0][t], ref_tok)
    lg, cache = decode(tp, target_cfg, cache, tok[:, None])
    tok = jnp.argmax(lg, -1)
print("lossless: speculative output == target greedy decoding  [OK]")
