"""Train a ~small model for a few hundred steps on the synthetic LM stream
(deliverable b: end-to-end training driver).

    PYTHONPATH=src python examples/train_small.py [--steps 200]

Exercises the full training substrate: model builder, flash-attention
custom VJP, chunked cross-entropy, AdamW, gradient flow through the
layer-group scan.  Loss should fall from ~ln(V) to near 0 on the
structured stream.
"""
import argparse

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_lm_batches
from repro.models import model as M
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = ModelConfig(name="train-small", arch_type="dense", n_layers=4,
                  d_model=128, n_heads=4, n_kv_heads=2, d_ff=384,
                  vocab_size=211, layer_pattern=("swa", "attn"),
                  sliding_window=32, dtype="float32", remat=False)
params = M.init_params(cfg, jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {n_params/1e6:.2f}M params, pattern {cfg.layer_pattern}")

opt_init, _ = make_optimizer("adamw")
data = make_lm_batches(args.batch, args.seq, cfg.vocab_size)
params, _, log = train_loop(cfg, params, opt_init(params), data,
                            args.steps, lr=2e-3,
                            log_every=max(args.steps // 10, 1))
for row in log:
    print(f"step {row['step']:4d}  loss {row['loss']:.4f}")
assert log[-1]["loss"] < log[0]["loss"] * 0.5, "did not learn"
print("training OK: loss fell >2x")
