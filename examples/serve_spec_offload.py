"""End-to-end serving driver (deliverable b): a Poisson request trace
through the full SpecOffload engine — offline placement, zig-zag prefill,
dual-batch interleaved decode with speculative verification, and the
continuous-batching scheduler (EOS retirement + mid-flight admission).

    PYTHONPATH=src python examples/serve_spec_offload.py [--arch mixtral-8x7b]

Uses the reduced config of the chosen architecture so it runs on CPU; the
pipeline structure (placement plan, interleaved batches, rollback, slot
scheduler) is the production one.
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.base import MISTRAL_7B
from repro.data.pipeline import synthetic_dataset
from repro.serving.engine import (SchedulerConfig, ServingEngine,
                                  latency_percentiles)
from repro.serving.trace import poisson_requests
from repro.sim.hardware import ENV1

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mixtral-8x7b")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--gen", type=int, default=12)
ap.add_argument("--rate", type=float, default=2.0, help="req/s (Poisson)")
args = ap.parse_args()

tcfg = get_config(args.arch).reduced(d_model=128)
dcfg = MISTRAL_7B.reduced(d_model=64, vocab=tcfg.vocab_size)

print(f"target: {tcfg.name} (reduced) | draft: {dcfg.name}")
eng = ServingEngine(tcfg, dcfg, ENV1,
                    config=SchedulerConfig(max_batch=2, n_cand=3))
eng.init_from_seed(0)

plan = eng.engine.placement
print("placement:", {e.name: e.tier for e in plan.entries[:4]}, "...")
for note in plan.notes:
    print("  note:", note)

ds = synthetic_dataset("samsum", n_prompts=args.requests,
                       vocab=tcfg.vocab_size)
rng = np.random.default_rng(1)
gens = rng.integers(max(2, args.gen // 2), args.gen + 1, args.requests)
reqs = poisson_requests([p[:24] for p in ds.prompts], gens.tolist(),
                        args.rate)
for r in reqs:
    eng.submit(r)

done = eng.run()
st = eng.stats()
toks = sum(len(r.result) for r in done)
print(f"\nserved {len(done)} requests / {toks} tokens in "
      f"{st['wall_s']:.1f}s ({eng.throughput(done):.2f} tok/s)")
print(f"occupancy={st['mean_occupancy']:.2f}, rounds={st['rounds']}, "
      f"fused compiles={st['fused_compiles']}")
print("ttft:", latency_percentiles(done, "ttft_s"))
print("e2e: ", latency_percentiles(done, "latency_s"))
print("first result tokens:", np.asarray(done[0].result).tolist())
