"""End-to-end serving driver (deliverable b): batched requests through the
full SpecOffload engine — offline placement, zig-zag prefill, dual-batch
interleaved decode with speculative verification.

    PYTHONPATH=src python examples/serve_spec_offload.py [--arch mixtral-8x7b]

Uses the reduced config of the chosen architecture so it runs on CPU; the
pipeline structure (placement plan, interleaved batches, rollback) is the
production one.
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import MISTRAL_7B
from repro.data.pipeline import synthetic_dataset
from repro.serving.engine import ServeRequest, ServingEngine
from repro.sim.hardware import ENV1

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mixtral-8x7b")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

tcfg = get_config(args.arch).reduced(d_model=128)
dcfg = MISTRAL_7B.reduced(d_model=64, vocab=tcfg.vocab_size)

print(f"target: {tcfg.name} ({sum(1 for _ in range(1))}x reduced) | "
      f"draft: {dcfg.name}")
eng = ServingEngine(tcfg, dcfg, ENV1, n_cand=3, batch_size=2)
eng.init_from_seed(0)

plan = eng.engine.placement
print("placement:", {e.name: e.tier for e in plan.entries[:4]}, "...")
for note in plan.notes:
    print("  note:", note)

ds = synthetic_dataset("samsum", n_prompts=args.requests,
                       vocab=tcfg.vocab_size)
for i, p in enumerate(ds.prompts):
    eng.submit(ServeRequest(i, p[:24], max_new_tokens=args.gen))

t0 = time.time()
done = eng.run()
dt = time.time() - t0
toks = sum(len(r.result) for r in done)
print(f"\nserved {len(done)} requests / {toks} tokens in {dt:.1f}s")
print("first result tokens:", np.asarray(done[0].result).tolist())
