"""Sampling-mode speculative decoding (Leviathan rule) and CLI launchers."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec_decode import sampled_acceptance, spec_round
from repro.models import model as M
from repro.models.transformer import init_cache

from conftest import tiny_config, tiny_draft_config

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_spec_round_sampling_mode_runs(jitted):
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    tp = M.init_params(tcfg, jax.random.PRNGKey(1))
    dp = M.init_params(dcfg, jax.random.PRNGKey(2))
    B, L, m = 4, 8, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0, 61)
    tc = init_cache(tcfg, B, 64)
    dc = init_cache(dcfg, B, 64)
    lg, tc = jitted["prefill"](tp, tcfg, toks, tc)
    _, dc = jitted["prefill"](dp, dcfg, toks, dc)
    r = spec_round(tp, tcfg, tc, dp, dcfg, dc, jnp.argmax(lg, -1), m,
                   key=jax.random.PRNGKey(7), sample=True)
    ne = np.asarray(r["n_emitted"])
    assert ((ne >= 1) & (ne <= m + 1)).all()
    assert (np.asarray(r["tokens"]) < tcfg.vocab_size).all()


def test_sampled_acceptance_identical_distributions_accept_all():
    """p_draft == p_target => acceptance prob 1 per token."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (256, 5, 32)) * 3
    # drafts sampled from the target distribution itself
    drafts = jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg[:4]),
        in_axes=(0, 0))(logits, jax.random.split(key, 256))
    a, nxt, nc = sampled_acceptance(drafts, logits[:, :4], logits,
                                    jax.random.PRNGKey(1))
    assert float(a.mean()) > 3.3       # ~4.0 expected, allow slack


def test_sampled_acceptance_disjoint_distributions_reject():
    """Draft puts mass where the target has none -> near-total rejection,
    and resampled tokens come from the target's support."""
    b, m, v = 128, 4, 16
    tl = jnp.full((b, m + 1, v), -30.0).at[:, :, :4].set(5.0)   # target: 0-3
    dl = jnp.full((b, m, v), -30.0).at[:, :, 8:12].set(5.0)     # draft: 8-11
    drafts = jnp.full((b, m), 9, jnp.int32)
    a, nxt, nc = sampled_acceptance(drafts, dl, tl, jax.random.PRNGKey(0))
    assert float(a.mean()) < 0.1
    assert (np.asarray(nxt) < 4).all()


def _cli(args):
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-3000:]
    return r.stdout


def test_serve_launcher_plan():
    out = _cli(["repro.launch.serve", "--arch", "mixtral-8x7b", "--plan",
                "--prompt-len", "300", "--gen", "32"])
    assert "policy" in out and "placement" in out


def test_train_launcher_production_plan():
    out = _cli(["repro.launch.train", "--arch", "llama3-405b",
                "--production-plan"])
    assert "adafactor" in out and "405" in out
