"""Asyncio serving front door: stream parity with the closed loop,
bounded-queue backpressure, weighted tenant fairness, priority
preemption losslessness, and graceful draining."""
import asyncio

import numpy as np
import pytest

from repro.serving.engine import (SchedulerConfig, ServeRequest,
                                  ServingEngine)
from repro.serving.server import AsyncServingServer, RequestRejected
from repro.serving.trace import replay_open_loop, tenant_poisson_requests

from conftest import greedy_reference, tiny_config, tiny_draft_config


def _engine(**kw):
    cfg = dict(max_batch=2, n_cand=2, clock="real", max_len=48)
    cfg.update(kw)
    se = ServingEngine(tiny_config(("attn",)), tiny_draft_config(),
                       config=SchedulerConfig(**cfg))
    se.init_from_seed(0)
    return se


def _prompts(n, rng, lo=5, hi=13):
    return [rng.integers(0, 61, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def test_server_requires_real_clock():
    se = _engine(clock="virtual")
    with pytest.raises(ValueError):
        AsyncServingServer(se)


def test_stream_parity_with_closed_loop(jitted):
    """Tokens streamed by the async front door are identical to the
    closed-loop run() output — and to the target-only greedy reference —
    for every request (per-sequence losslessness carries over)."""
    rng = np.random.default_rng(0)
    prompts = _prompts(5, rng)
    gens = [int(g) for g in rng.integers(3, 8, 5)]

    closed = _engine(clock="virtual")
    reqs = [ServeRequest(i, p, g) for i, (p, g) in
            enumerate(zip(prompts, gens))]
    for r in reqs:
        closed.submit(r)
    closed_done = {r.rid: list(map(int, r.result))
                   for r in closed.run()}

    se = _engine()

    async def drive():
        async with AsyncServingServer(se, max_queue=8) as srv:
            handles = [await srv.submit(p, g, rid=i)
                       for i, (p, g) in enumerate(zip(prompts, gens))]
            outs = await asyncio.gather(
                *[srv.collect(h) for h in handles])
        return {h.rid: o for h, o in zip(handles, outs)}

    streamed = asyncio.run(drive())
    assert streamed == closed_done
    for i, (p, g) in enumerate(zip(prompts, gens)):
        ref = greedy_reference(se.engine.tp, se.target_cfg, p[None, :],
                               g, 64, jitted)
        assert streamed[i] == list(map(int, np.asarray(ref)[0]))
    assert not se.has_work()                      # clean drain
    assert se.stats()["fused_compiles"] == 1


def test_backpressure_bounds_queue_and_timeout_rejects():
    """submit() awaits while the bounded admission queue is full; a
    timeout turns starvation into RequestRejected and the rejection
    counter ticks (the engine-level graceful path, reused)."""
    se = _engine(max_batch=1)
    rng = np.random.default_rng(1)
    prompts = _prompts(8, rng)

    async def drive():
        rejected = []
        async with AsyncServingServer(se, max_queue=2,
                                      submit_timeout_s=0.02) as srv:
            handles = []
            for i, p in enumerate(prompts):
                try:
                    handles.append(await srv.submit(p, 6, rid=i))
                except RequestRejected as e:
                    rejected.append(e.reason)
                assert srv._depth() <= 2          # the bound holds
            outs = await asyncio.gather(
                *[srv.collect(h) for h in handles])
        return handles, outs, rejected

    handles, outs, rejected = asyncio.run(drive())
    assert all(r == "backpressure_timeout" for r in rejected)
    assert len(handles) + len(rejected) == len(prompts)
    assert all(len(o) == 6 for o in outs)         # admitted ones finish
    if rejected:
        assert se.obs.metrics.counter(
            "serve_requests_rejected_total").value(
                reason="backpressure_timeout", tenant="default") \
            == len(rejected)


def test_submit_after_drain_rejected():
    se = _engine()

    async def drive():
        srv = AsyncServingServer(se)
        await srv.start()
        h = await srv.submit(np.arange(5, dtype=np.int32), 3)
        toks = await srv.collect(h)
        await srv.drain()
        assert len(toks) == 3
        with pytest.raises(RequestRejected):
            await srv.submit(np.arange(5, dtype=np.int32), 3)

    asyncio.run(drive())


def test_weighted_fairness_two_tenants():
    """A flood from tenant A must not starve tenant B: with qos fair
    ordering, B's first admission beats A's backlog even though every
    A request was submitted first."""
    se = _engine(max_batch=1, qos=True,
                 tenant_weights={"a": 1.0, "b": 1.0})
    rng = np.random.default_rng(2)

    async def drive():
        async with AsyncServingServer(se, max_queue=16) as srv:
            a = [await srv.submit(p, 6, tenant="a")
                 for p in _prompts(6, rng)]
            b = [await srv.submit(p, 6, tenant="b")
                 for p in _prompts(2, rng)]
            await asyncio.gather(*[srv.collect(h) for h in a + b])
        return a, b

    a, b = asyncio.run(drive())
    # all of A was queued before any of B, yet B's last admission beats
    # A's last: the fair share interleaved the tenants
    assert max(r.admitted_s for r in b) < max(r.admitted_s for r in a)
    assert all(len(r.result) == 6 for r in a + b)


def test_preemption_lossless_and_prioritized(jitted):
    """A high-priority arrival preempts a long-tail decode (both slots
    busy); the victim is requeued with saved progress and its resumed
    stream still matches the uninterrupted greedy reference exactly."""
    se = _engine(max_batch=1, qos=True, preempt=True,
                 preempt_min_remaining=2, max_len=64)
    rng = np.random.default_rng(3)
    long_p = _prompts(2, rng)
    short_p = _prompts(1, rng)[0]
    longs = [ServeRequest(i, p, 14, priority=2)
             for i, p in enumerate(long_p)]
    short = ServeRequest(9, short_p, 3, priority=0)

    # drive run_step() directly (closed loop) for determinism: fill both
    # slots with low-priority long decodes first
    for r in longs:
        se.submit(r)
    for _ in range(4):
        se.run_step()
    assert se.has_live() and not any(s.done
                                     for half in se._slots for s in half)
    se.submit(short)
    done = se.run()
    assert {r.rid for r in done} | {r.rid for r in []} >= {9}
    victims = [r for r in longs if r.preemptions > 0]
    assert victims, "a long decode should have been preempted"
    assert se.preempted_total == len(victims) >= 1
    # the high-priority request finished before the preempted long one
    assert short.finished_s <= min(r.finished_s for r in victims)
    # losslessness: every stream equals its uninterrupted greedy decode
    for r in longs + [short]:
        ref = greedy_reference(se.engine.tp, se.target_cfg,
                               np.asarray(r.prompt)[None, :],
                               r.max_new_tokens, 64, jitted)
        assert (np.asarray(ref)[0] == r.result).all(), f"rid {r.rid}"
    assert se.stats()["fused_compiles"] == 1


def test_open_loop_replay_multi_tenant():
    """tenant_poisson_requests + replay_open_loop: deterministic tenant
    labeling, token-by-token streaming for every request, per-tenant
    metrics recorded, clean drain."""
    rng = np.random.default_rng(4)
    prompts = _prompts(6, rng)
    tenants = {"acme": {"share": 2.0, "priority": 1},
               "beta": {"share": 1.0, "priority": 0}}
    reqs = tenant_poisson_requests(prompts, 5, 50.0, tenants, seed=5)
    again = tenant_poisson_requests(prompts, 5, 50.0, tenants, seed=5)
    assert [r.tenant for r in reqs] == [r.tenant for r in again]
    assert len({r.tenant for r in reqs}) == 2

    se = _engine(qos=True, preempt=True)

    async def drive():
        async with AsyncServingServer(se, max_queue=8) as srv:
            tokens, handles = await replay_open_loop(srv, reqs,
                                                     speed=50.0)
            report = srv.tenant_report()
        return tokens, handles, report

    tokens, handles, report = asyncio.run(drive())
    assert len(handles) == len(reqs) and not se.has_work()
    assert all(len(t) == 5 for t in tokens.values())
    assert set(report) == {"acme", "beta"}
    assert sum(d["requests"] for d in report.values()) == len(reqs)
    # per-tenant TTFT histogram landed in the registry
    snap = se.metrics()["metrics"]["histograms"]["serve_ttft_seconds"]
    assert sum(s["count"] for s in snap.values()) == len(reqs)
