"""Tree speculation: layout/ancestor-mask construction, masked kernel vs
reference parity, analytic acceptance model vs brute-force enumeration,
round/pipeline/serving losslessness, and the acceptance metrics."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interleave import InterleavedPipeline
from repro.core.pipeline import SpecOffloadEngine
from repro.core.spec_decode import (MAX_TREE_NODES, acceptance_pmf,
                                    acceptance_pmf_tree, expected_generated,
                                    expected_generated_tree,
                                    record_acceptance, spec_round_tree,
                                    tree_layout, tree_n_nodes, tree_spec,
                                    tree_supported)
from repro.kernels.decode_attention import (decode_attention,
                                            paged_decode_attention)
from repro.kernels.ref import decode_attention_ref, paged_decode_attention_ref
from repro.obs.metrics import Registry
from repro.serving.engine import (SchedulerConfig, ServeRequest,
                                  ServingEngine)

from conftest import greedy_reference, tiny_config


def _attn_draft():
    return tiny_config(("attn",), n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=1, d_ff=64)


# ---------------------------------------------------------------------------
# layout + ancestor masks


def test_tree_layout_hand_checked():
    lay = tree_layout((3, 2))
    assert lay["n_nodes"] == 10                       # 1 + 3 + 6
    assert lay["depth"].tolist() == [0, 1, 1, 1, 2, 2, 2, 2, 2, 2]
    assert lay["parent"].tolist() == [0, 0, 0, 0, 1, 1, 2, 2, 3, 3]
    assert lay["level_offsets"].tolist() == [0, 1, 4]
    assert lay["first_child"].tolist() == [1, 4, 6, 8, -1, -1, -1, -1,
                                           -1, -1]


@pytest.mark.parametrize("branching", [(1,), (2,), (3, 2), (2, 2, 2),
                                       (4, 1, 2)])
def test_ancestor_mask_vs_walk(branching):
    """anc_mask[i, j] iff j is on the root path of i (or i itself) —
    checked against an explicit parent-pointer walk per node."""
    lay = tree_layout(branching)
    n, parent = int(lay["n_nodes"]), lay["parent"]
    for i in range(n):
        path = {i}
        j = i
        while j != 0:
            j = int(parent[j])
            path.add(j)
        expect = np.zeros(n, bool)
        expect[list(path)] = True
        assert (lay["anc_mask"][i] == expect).all(), f"node {i}"
    # int32 bitmask encodes the same rows
    for i in range(n):
        bits = int(lay["anc_bits"][i])
        got = [(bits >> j) & 1 == 1 for j in range(n)]
        assert got == lay["anc_mask"][i].tolist()


def test_tree_node_cap():
    with pytest.raises(ValueError):
        tree_layout((2,) * 5)                          # 63 nodes > 31
    assert tree_n_nodes((2, 2, 2, 2)) == 31 == MAX_TREE_NODES


def test_tree_spec_levels():
    full = tree_spec((3, 2))
    assert full["prev"] == 0 and full["mask"].shape == (10, 10)
    lvl2 = tree_spec((3, 2), level=2)
    assert lvl2["prev"] == 4 and lvl2["mask"].shape == (6, 10)
    assert lvl2["depths"].tolist() == [2] * 6


def test_tree_supported_gating():
    assert tree_supported(tiny_config(("attn",)))
    assert not tree_supported(tiny_config(("swa",)))
    assert not tree_supported(tiny_config(("attn", "swa")))


# ---------------------------------------------------------------------------
# analytic acceptance model vs brute-force enumeration


@pytest.mark.parametrize("branching,p", [((2,), 0.3), ((3, 2), 0.5),
                                         ((2, 2), 0.7), ((1, 1, 1), 0.4)])
def test_pmf_matches_bruteforce(branching, p):
    """Enumerate every outcome of the per-child i.i.d. Bernoulli(p)
    acceptance model and histogram the reached depth."""
    lay = tree_layout(branching)
    n_children = int(lay["n_nodes"]) - 1
    pmf = np.zeros(len(branching) + 1)
    for bits in itertools.product([0, 1], repeat=n_children):
        prob = np.prod([p if b else 1 - p for b in bits])
        match = {i + 1: b for i, b in enumerate(bits)}
        # greedy acceptance keeps ONE node per level (the target's unique
        # greedy path): walk the first matching child of the current node
        cur, depth = 0, 0
        for d in range(1, len(branching) + 1):
            fc = int(lay["first_child"][cur])
            nxt = next((fc + j for j in range(branching[d - 1])
                        if match[fc + j]), None)
            if nxt is None:
                break
            cur, depth = nxt, d
        pmf[depth] += prob
    np.testing.assert_allclose(np.asarray(acceptance_pmf_tree(p, branching)),
                               pmf, atol=1e-12)
    e_brute = float((pmf * (np.arange(len(pmf)) + 1)).sum())
    assert abs(expected_generated_tree(p, branching) - e_brute) < 1e-12


def test_tree_model_chain_degeneracy():
    """A (1, 1, ..., 1) tree is exactly the linear chain model."""
    for p in (0.2, 0.5, 0.9):
        for m in (1, 3, 5):
            np.testing.assert_allclose(
                np.asarray(acceptance_pmf_tree(p, (1,) * m)),
                np.asarray(acceptance_pmf(p, m)), atol=1e-6)
            assert abs(expected_generated_tree(p, (1,) * m)
                       - expected_generated(p, m)) < 1e-6
    assert expected_generated_tree(1.0, (2, 2)) == 3.0


# ---------------------------------------------------------------------------
# masked kernels vs reference gather (interpret mode)


@pytest.mark.parametrize("branching", [(2,), (3, 2), (2, 2, 2)])
def test_tree_kernel_matches_ref_contiguous(branching):
    lay = tree_layout(branching)
    n = int(lay["n_nodes"])
    b, hq, hkv, d, skv = 3, 4, 2, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32)
    lengths = jnp.array([20 + n, 11 + n, 33 + n], jnp.int32)
    out = decode_attention(q, k, v, lengths,
                           anc_bits=jnp.asarray(lay["anc_bits"]),
                           block_k=32, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths, anc_mask=lay["anc_mask"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("branching", [(3, 2), (2, 2, 2)])
def test_tree_kernel_matches_ref_paged(branching):
    lay = tree_layout(branching)
    n = int(lay["n_nodes"])
    b, hq, hkv, d, bs, nb = 3, 4, 2, 16, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), jnp.float32)
    kp = jax.random.normal(ks[1], (nb, bs, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (nb, bs, hkv, d), jnp.float32)
    bt = jnp.asarray(np.array([[1, 2, 3, 4], [5, 6, 0, 0],
                               [7, 8, 9, 10]], np.int32))
    lengths = jnp.array([30 + n, 17 + n, 40 + n], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lengths,
                                 anc_bits=jnp.asarray(lay["anc_bits"]),
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lengths,
                                     anc_mask=lay["anc_mask"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# round-level losslessness


@pytest.mark.parametrize("branching", [(2,), (3, 2)])
def test_spec_round_tree_lossless(jitted, branching):
    """Tree-verified emission is token-identical to target-only greedy,
    with both a disagreeing random draft and a fully-agreeing one."""
    from functools import partial
    from repro.models.transformer import init_cache
    tcfg = tiny_config(("attn",))
    tp = M_params(tcfg, 0)
    b, L, steps = 3, 5, 14
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, L), 0,
                              tcfg.vocab_size)
    ref = np.asarray(greedy_reference(tp, tcfg, toks, steps, 96, jitted))
    round_fn = jax.jit(partial(spec_round_tree, sample=False),
                       static_argnames=("target_cfg", "draft_cfg",
                                        "branching", "mesh"))
    for dcfg, dp in ((_attn_draft(), M_params(_attn_draft(), 1)),
                     (tcfg, tp)):
        tc, dc = init_cache(tcfg, b, 96), init_cache(dcfg, b, 96)
        lg, tc = jitted["prefill"](tp, tcfg, toks, tc)
        _, dc = jitted["prefill"](dp, dcfg, toks, dc)
        t_next = jnp.argmax(lg, -1)
        streams = [[int(t)] for t in np.asarray(t_next)]
        for _ in range(steps):
            out = round_fn(tp, tcfg, tc, dp, dcfg, dc, t_next, branching)
            tc, dc, t_next = (out["target_cache"], out["draft_cache"],
                              out["t_next"])
            tr, nr = np.asarray(out["tokens"]), np.asarray(out["n_emitted"])
            for r in range(b):
                streams[r].extend(tr[r, :int(nr[r])].tolist())
        for r in range(b):
            assert streams[r][:steps] == ref[r].tolist(), f"row {r}"
        if dcfg is tcfg:
            # an agreeing draft must be accepted to full depth
            assert (np.asarray(out["n_accept"]) == len(branching)).all()


def test_spec_round_tree_sampled_valid(jitted):
    """Sampled tree acceptance: emitted tokens stay in-vocab, counts in
    range, and the caches stay consistent across rounds."""
    from functools import partial
    from repro.models.transformer import init_cache
    tcfg = tiny_config(("attn",))
    dcfg = _attn_draft()
    tp, dp = M_params(tcfg, 0), M_params(dcfg, 1)
    b, L = 2, 5
    toks = jax.random.randint(jax.random.PRNGKey(9), (b, L), 0,
                              tcfg.vocab_size)
    tc, dc = init_cache(tcfg, b, 96), init_cache(dcfg, b, 96)
    lg, tc = jitted["prefill"](tp, tcfg, toks, tc)
    _, dc = jitted["prefill"](dp, dcfg, toks, dc)
    t_next = jnp.argmax(lg, -1)
    round_fn = jax.jit(partial(spec_round_tree, sample=True),
                       static_argnames=("target_cfg", "draft_cfg",
                                        "branching", "mesh"))
    key = jax.random.PRNGKey(0)
    for i in range(4):
        key, sub = jax.random.split(key)
        out = round_fn(tp, tcfg, tc, dp, dcfg, dc, t_next, (2, 2), key=sub)
        tc, dc, t_next = (out["target_cache"], out["draft_cache"],
                          out["t_next"])
        a = np.asarray(out["n_accept"])
        assert ((0 <= a) & (a <= 2)).all()
        toks_out = np.asarray(out["tokens"])
        n = np.asarray(out["n_emitted"])
        for r in range(b):
            assert (toks_out[r, :n[r]] >= 0).all()
            assert (toks_out[r, :n[r]] < tcfg.vocab_size).all()
    assert int(np.asarray(tc["pos"])[0]) > L


def M_params(cfg, seed):
    from repro.models import model as M
    return M.init_params(cfg, jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# pipeline + serving losslessness (incl. mid-flight paged admission)


def test_tree_pipeline_single_compile_lossless(jitted):
    tcfg = tiny_config(("attn",))
    dcfg = _attn_draft()
    eng = SpecOffloadEngine(tcfg, dcfg)
    eng.init_from_seed(0)
    b, L, gen = 4, 6, 10
    prompts = jax.random.randint(jax.random.PRNGKey(3), (b, L), 0,
                                 tcfg.vocab_size)
    ref = np.asarray(greedy_reference(eng.tp, tcfg, prompts, gen, 96,
                                      jitted))
    states = [eng.prefill_batch(pt, 96) for pt in (prompts[:2], prompts[2:])]
    pipe = eng.pipeline(0, tree=(3, 2))
    s0, s1, _ = pipe.run(states, gen)
    out, _ = eng.finalize([s0, s1], gen)
    assert (out == ref).all()
    assert pipe.trace_counts["fused"] == 1
    assert pipe.trace_counts["rollback"] == 0      # commit is in-fused


def test_tree_pipeline_rejects_swa():
    tcfg = tiny_config(("attn",))
    bad = tiny_config(("swa",))
    with pytest.raises(ValueError):
        InterleavedPipeline(None, tcfg, None, bad, 0, tree=(2,))
    with pytest.raises(ValueError):
        ServingEngine(tcfg, bad, config=SchedulerConfig(spec_tree=(2,)))


def test_serving_tree_lossless_midflight_admission(jitted):
    """Tree-mode continuous batching under retirement + mid-flight paged
    admission stays token-identical to target-only greedy decode, with
    exactly one fused compile."""
    tcfg = tiny_config(("attn",))
    se = ServingEngine(tcfg, _attn_draft(),
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              spec_tree=(2, 2)))
    se.init_from_seed(0)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):                      # 6 reqs > 4 slots: forced churn
        p = rng.integers(0, 61, int(rng.integers(5, 13))).astype(np.int32)
        reqs.append(ServeRequest(i, p,
                                 max_new_tokens=int(rng.integers(3, 10))))
        se.submit(reqs[-1])
    done = se.run()
    assert len(done) == 6 and se.pending() == 0
    st = se.stats()
    assert st["fused_compiles"] == 1
    assert st["spec_mode"] == "tree" and st["spec_tree"] == (2, 2)
    for r in reqs:
        ref = greedy_reference(se.engine.tp, tcfg,
                               np.asarray(r.prompt)[None, :],
                               r.max_new_tokens, 96, jitted)
        assert (np.asarray(ref)[0] == r.result).all(), f"rid {r.rid}"
    kv = se.kv_stats()
    assert kv["paged"] and all(a["used"] == 0 for a in kv["allocators"])
    prom = se.prometheus()
    assert 'spec_tokens_wasted_total{mode="tree"}' in prom
    assert "spec_accept_depth_total" in prom


def test_serving_tree_acceptance_replan():
    """The acceptance-drift trigger runs the joint chain-vs-tree search
    and records the suggested tree budget."""
    tcfg = tiny_config(("attn",))
    se = ServingEngine(tcfg, _attn_draft(),
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              spec_tree=(2, 2),
                                              replan_accept_drift=0.05,
                                              replan_interval=2))
    se.init_from_seed(0)
    rng = np.random.default_rng(1)
    for i in range(3):
        se.submit(ServeRequest(i, rng.integers(0, 61, 8).astype(np.int32),
                               12))
    se.run()
    # a random tiny draft accepts ~never: the measured-acceptance EMA
    # decays away from the planned 0.7 and crosses the 0.05 drift band
    assert len(se.replan_events) >= 1
    ev = se.replan_events[0]
    assert "tree" in ev and "accept_rate" in ev
    assert ev["accept_rate"] < 0.7 - 0.05
    assert se.suggested_policy is not None


# ---------------------------------------------------------------------------
# metrics


def test_record_acceptance_tree_counters():
    reg = Registry()
    # two sequences, depth cap 2, 6 candidates verified per round (tree
    # (2,2) has 7 nodes -> 6 non-root candidates)
    record_acceptance(reg, np.array([2, 0]), 2, n_draft=6, mode="tree")
    snap = reg.snapshot()
    c = snap["counters"]
    assert c["spec_tokens_accepted_total"]['{mode="tree"}'] == 2.0
    assert c["spec_tokens_wasted_total"]['{mode="tree"}'] == 10.0
    assert c["spec_verify_rounds_total"]['{mode="tree"}'] == 2.0
    depth = c["spec_accept_depth_total"]
    assert depth['{depth="1",mode="tree"}'] == 1.0
    assert depth['{depth="2",mode="tree"}'] == 1.0
