"""int8-quantized KV cache: accuracy, losslessness-within-itself, memory."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.transformer import init_cache

from conftest import tiny_config, tiny_draft_config


def _cfgs():
    fp = tiny_config(("attn",))
    return fp, dataclasses.replace(fp, kv_cache_dtype="int8")


def test_int8_kv_close_to_fp_and_greedy_identical(jitted):
    fp, q8 = _cfgs()
    p = M.init_params(fp, jax.random.PRNGKey(0))
    B, L, T = 2, 10, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + T), 0, 61)

    def run(cfg):
        c = init_cache(cfg, B, 24)
        lg, c = jitted["prefill"](p, cfg, toks[:, :L], c)
        outs = [lg]
        for t in range(T):
            lg, c = jitted["decode_step"](p, cfg, c, toks[:, L + t:L + t + 1])
            outs.append(lg)
        return jnp.stack(outs)

    a, b = run(fp), run(q8)
    rel = float((jnp.abs(a - b) / (jnp.abs(a) + 1)).max())
    assert rel < 0.05, rel
    assert (jnp.argmax(a, -1) == jnp.argmax(b, -1)).all()


def test_int8_kv_spec_decode_self_consistent(jitted):
    """Spec decoding against the int8-cached target equals that target's
    own greedy decoding (losslessness is w.r.t. the same cache numerics)."""
    from conftest import greedy_reference
    from repro.core.spec_decode import spec_round
    _, q8 = _cfgs()
    dcfg = tiny_draft_config()
    tp = M.init_params(q8, jax.random.PRNGKey(1))
    dp = M.init_params(dcfg, jax.random.PRNGKey(2))
    B, L, T, m = 2, 8, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0, 61)
    ref = greedy_reference(tp, q8, toks, T, 64, jitted)
    tc, dc = init_cache(q8, B, 64), init_cache(dcfg, B, 64)
    lg, tc = jitted["prefill"](tp, q8, toks, tc)
    _, dc = jitted["prefill"](dp, dcfg, toks, dc)
    t_next = jnp.argmax(lg, -1)
    spec = jax.jit(spec_round, static_argnames=(
        "target_cfg", "draft_cfg", "n_cand", "mesh", "sample"))
    outs = [[int(t_next[i])] for i in range(B)]
    for _ in range(20):
        if min(len(o) for o in outs) >= T:
            break
        r = spec(tp, q8, tc, dp, dcfg, dc, t_next, m)
        tc, dc, t_next = r["target_cache"], r["draft_cache"], r["t_next"]
        for i in range(B):
            for j in range(int(r["n_emitted"][i])):
                outs[i].append(int(r["tokens"][i, j]))
    for i in range(B):
        assert outs[i][:T] == list(np.asarray(ref[i, :T]))


def test_int8_cache_memory_halved():
    fp, q8 = _cfgs()
    a = init_cache(fp, 2, 64)
    b = init_cache(q8, 2, 64)
    bytes_of = lambda c: sum(x.size * x.dtype.itemsize
                             for x in jax.tree.leaves(c["layers"]))
    # int8 values + f32 per-row scales vs fp cache
    assert bytes_of(b) < 0.75 * bytes_of(a)
