"""Per-kernel allclose tests: sweep shapes/dtypes, compare the Pallas
kernel (interpret mode on CPU) against the pure-jnp ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, key=KEY):
    return jax.random.normal(key, shape).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", [
    (1, 4, 2, 128, 128, 64, True, None),
    (2, 2, 1, 256, 256, 128, True, None),
    (1, 4, 4, 128, 128, 64, True, 40),     # sliding window
    (1, 2, 2, 100, 100, 64, True, None),   # non-multiple seq (padding)
    (2, 8, 2, 128, 128, 64, False, None),  # bidirectional (encoder)
])
def test_flash_attention(dtype, b, hq, hkv, sq, skv, d, causal, window):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, hq, sq, d), dtype, ks[0])
    k = _rand((b, hkv, skv, d), dtype, ks[1])
    v = _rand((b, hkv, skv, d), dtype, ks[2])
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,m,skv,d,window", [
    (2, 4, 2, 1, 256, 64, None),     # plain decode
    (2, 4, 2, 5, 256, 64, None),     # speculative verify (n_cand=4)
    (1, 8, 1, 4, 512, 128, None),    # MQA
    (2, 2, 2, 3, 300, 64, None),     # non-multiple cache length
    (1, 4, 2, 4, 256, 64, 64),       # sliding window cache
])
def test_decode_attention(dtype, b, hq, hkv, m, skv, d, window):
    ks = jax.random.split(KEY, 4)
    q = _rand((b, hq, m, d), dtype, ks[0])
    k = _rand((b, hkv, skv, d), dtype, ks[1])
    v = _rand((b, hkv, skv, d), dtype, ks[2])
    lengths = jax.random.randint(ks[3], (b,), m + 8,
                                 skv + 1).astype(jnp.int32)
    got = ops.decode_attention(q, k, v, lengths, window=window,
                               block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def _paged_case(key, b, hkv, mbs, bs, d, dtype, quant=False):
    """Random pool + disjoint per-sequence block tables + lengths."""
    ks = jax.random.split(key, 4)
    nb = b * mbs + 3                     # a few never-referenced blocks
    perm = jax.random.permutation(ks[0], nb)[:b * mbs].reshape(b, mbs)
    if quant:
        kp = jax.random.randint(ks[1], (nb, bs, hkv, d), -127,
                                128).astype(jnp.int8)
        vp = jax.random.randint(ks[2], (nb, bs, hkv, d), -127,
                                128).astype(jnp.int8)
        scs = jax.random.uniform(ks[3], (2, nb, bs, hkv, 1),
                                 minval=0.01, maxval=0.1)
        scales = dict(k_scale=scs[0], v_scale=scs[1])
    else:
        kp = _rand((nb, bs, hkv, d), dtype, ks[1])
        vp = _rand((nb, bs, hkv, d), dtype, ks[2])
        scales = dict(k_scale=None, v_scale=None)
    return kp, vp, perm.astype(jnp.int32), scales


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,m,mbs,bs,d,quant", [
    (2, 4, 2, 1, 4, 16, 64, False),    # plain paged decode
    (2, 4, 2, 5, 4, 16, 64, False),    # speculative verify (n_cand=4)
    (1, 8, 1, 4, 8, 8, 128, False),    # MQA, small blocks
    (2, 2, 2, 3, 3, 32, 64, True),     # int8 cold blocks + scales
    (1, 4, 2, 4, 5, 16, 64, True),     # int8, MBS not covering full pool
])
def test_paged_decode_attention(dtype, b, hq, hkv, m, mbs, bs, d, quant):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, hq, m, d), dtype, ks[0])
    kp, vp, bt, scales = _paged_case(ks[1], b, hkv, mbs, bs, d, dtype, quant)
    lengths = jax.random.randint(ks[2], (b,), m + 1,
                                 mbs * bs + 1).astype(jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, lengths,
                                     interpret=True, **scales)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths, **scales)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_paged_matches_contiguous_decode():
    """A paged cache holding the same rows as a contiguous cache must give
    the contiguous kernel's output exactly (table = identity shuffle)."""
    b, hq, hkv, m, bs, mbs, d = 2, 4, 2, 3, 16, 4, 64
    skv = bs * mbs
    ks = jax.random.split(KEY, 4)
    q = _rand((b, hq, m, d), jnp.float32, ks[0])
    k = _rand((b, hkv, skv, d), jnp.float32, ks[1])
    v = _rand((b, hkv, skv, d), jnp.float32, ks[2])
    lengths = jnp.array([skv - 5, skv - 17], jnp.int32)
    # pool rows [seq b, logical block j] live at physical block b*mbs + j
    kp = k.transpose(0, 2, 1, 3).reshape(b * mbs, bs, hkv, d)
    vp = v.transpose(0, 2, 1, 3).reshape(b * mbs, bs, hkv, d)
    bt = jnp.arange(b * mbs, dtype=jnp.int32).reshape(b, mbs)
    want = ops.decode_attention(q, k, v, lengths, block_k=bs,
                                interpret=True)
    got = ops.paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", [
    (4, 128, 64, 256),
    (2, 100, 128, 300),     # non-multiples (padding)
    (8, 64, 32, 128),
])
def test_moe_ffn(dtype, e, c, d, f):
    ks = jax.random.split(KEY, 4)
    buf = _rand((e, c, d), dtype, ks[0])
    wg = _rand((e, d, f), dtype, ks[1]) * 0.1
    wu = _rand((e, d, f), dtype, ks[2]) * 0.1
    wd = _rand((e, f, d), dtype, ks[3]) * 0.1
    got = ops.moe_ffn(buf, wg, wu, wd, block_c=64, block_f=128,
                      interpret=True)
    want = ref.moe_ffn_ref(buf, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("b,s,w", [(2, 64, 256), (1, 128, 100), (4, 32, 512)])
def test_rglru_scan(b, s, w):
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(_rand((b, s, w), jnp.float32, ks[0]))
    g = _rand((b, s, w), jnp.float32, ks[1])
    h0 = _rand((b, w), jnp.float32, ks[2])
    got = ops.rglru_scan(a, g, h0, block_w=128, interpret=True)
    want = ref.rglru_scan_ref(a, g, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,s,hd", [(1, 2, 32, 64), (2, 4, 16, 64),
                                      (1, 1, 64, 128)])
def test_wkv6(b, h, s, hd):
    ks = jax.random.split(KEY, 6)
    r = _rand((b, h, s, hd), jnp.float32, ks[0])
    k = _rand((b, h, s, hd), jnp.float32, ks[1])
    v = _rand((b, h, s, hd), jnp.float32, ks[2])
    w = jax.nn.sigmoid(_rand((b, h, s, hd), jnp.float32, ks[3]))
    u = _rand((h, hd), jnp.float32, ks[4]) * 0.1
    s0 = _rand((b, h, hd, hd), jnp.float32, ks[5]) * 0.1
    got_y, got_s = ops.wkv6(r, k, v, w, u, s0, interpret=True)
    want_y, want_s = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=2e-4, atol=2e-4)


def test_flash_matches_model_attention():
    """Kernel output equals the model's chunked-attention path."""
    from repro.models.attention import attention_chunked
    b, hq, hkv, s, d = 2, 4, 2, 96, 64
    ks = jax.random.split(KEY, 3)
    q = _rand((b, s, hq, d), jnp.float32, ks[0])
    k = _rand((b, s, hkv, d), jnp.float32, ks[1])
    v = _rand((b, s, hkv, d), jnp.float32, ks[2])
    pos = jnp.arange(s)
    model_out = attention_chunked(q, k, v, pos, pos, d ** -0.5,
                                  kv_chunk=32)
    kern_out = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3),
                                   block_q=32, block_k=32, interpret=True)
    kern_out = kern_out.transpose(0, 2, 1, 3).reshape(b, s, hq * d)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out),
                               rtol=2e-4, atol=2e-4)
