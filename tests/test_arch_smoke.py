"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(<=2 groups, d_model<=512, <=4 experts) runs one forward/train step and one
decode step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, PAPER_MODELS
from repro.models import model as M
from repro.models.transformer import init_cache

ALL = {**ARCHS, **PAPER_MODELS}


def _smoke_cfg(name):
    cfg = ALL[name].reduced(d_model=128)
    return cfg


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size)}
    if cfg.encoder_decoder:
        batch["encoder_frames"] = jax.random.normal(
            k, (b, cfg.encoder_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ALL))
def test_smoke_forward_and_loss(name):
    cfg = _smoke_cfg(name)
    assert cfg.d_model <= 512 and cfg.n_groups <= 2
    assert not cfg.is_moe or cfg.n_experts <= 4
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(M.forward_train, static_argnums=(1,))(p, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"
    loss, grads = jax.value_and_grad(
        lambda pp: M.loss_fn(pp, cfg, batch))(p)
    assert np.isfinite(float(loss)), name
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), f"{name}: non-finite grads"


@pytest.mark.parametrize("name", sorted(ALL))
def test_smoke_serve_step(name):
    cfg = _smoke_cfg(name)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    cache = init_cache(cfg, b, 32)
    kw = {}
    if cfg.encoder_decoder:
        kw["encoder_frames"] = batch["encoder_frames"]
    lg, cache = M.prefill(p, cfg, batch["tokens"], cache, **kw)
    assert lg.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), name
    tok = jnp.argmax(lg, -1)[:, None]
    lg2, cache = jax.jit(M.decode_step, static_argnums=(1,))(p, cfg, cache,
                                                             tok)
    assert lg2.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2).all()), name
    assert (np.asarray(cache["pos"]) == s + 1).all()


def test_full_config_param_counts():
    """Full (non-reduced) configs match their public parameter counts."""
    expect = {
        "chameleon-34b": (34e9, 0.10),
        "phi3.5-moe-42b-a6.6b": (42e9, 0.10),
        "phi3-medium-14b": (14e9, 0.10),
        "recurrentgemma-2b": (2.7e9, 0.30),
        "llama3-405b": (405e9, 0.05),
        "whisper-base": (72e6, 0.35),
        "llama4-maverick-400b-a17b": (400e9, 0.15),
        "gemma3-12b": (12e9, 0.20),
        "rwkv6-7b": (7e9, 0.30),
        "starcoder2-7b": (7e9, 0.15),
        "mixtral-8x7b": (46.7e9, 0.03),
        "mixtral-8x22b": (141e9, 0.03),
        "mistral-7b": (7.2e9, 0.03),
    }
    for name, (target, tol) in expect.items():
        got = ALL[name].param_count()
        assert abs(got - target) / target < tol, (name, got / 1e9)


def test_active_params_moe():
    moe = ALL["phi3.5-moe-42b-a6.6b"]
    assert 5e9 < moe.active_param_count() < 9e9
    mav = ALL["llama4-maverick-400b-a17b"]
    assert mav.active_param_count() < 30e9
