"""Shared fixtures/utilities for the test suite.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches see
the single real CPU device.  The multi-pod dry-run sets its own flags (and
runs as a subprocess in tests that need many devices).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as M


def tiny_config(pattern=("attn",), arch="dense", n_layers=None, **kw):
    return ModelConfig(
        name="tiny", arch_type=arch,
        n_layers=n_layers or (len(pattern) * 2),
        d_model=kw.pop("d_model", 64), n_heads=kw.pop("n_heads", 4),
        n_kv_heads=kw.pop("n_kv_heads", 2), d_ff=kw.pop("d_ff", 128),
        vocab_size=kw.pop("vocab_size", 61), layer_pattern=pattern,
        sliding_window=kw.pop("sliding_window", 8),
        dtype="float32", remat=False, **kw)


def tiny_draft_config(vocab_size=61):
    return ModelConfig(
        name="tiny-draft", arch_type="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=vocab_size,
        layer_pattern=("swa",), sliding_window=8, dtype="float32",
        remat=False)


@pytest.fixture(scope="session")
def jitted():
    """Jitted model entry points (cfg/mesh static)."""
    return {
        "forward_train": jax.jit(M.forward_train, static_argnums=(1,),
                                 static_argnames=("mesh",)),
        "prefill": jax.jit(M.prefill, static_argnums=(1,),
                           static_argnames=("mesh",)),
        "decode_step": jax.jit(M.decode_step, static_argnums=(1,),
                               static_argnames=("mesh",)),
        "decode": jax.jit(M.decode, static_argnums=(1,),
                          static_argnames=("mesh",)),
        "commit": jax.jit(M.commit, static_argnums=(0, 4)),
    }


def greedy_reference(params, cfg, toks, steps, maxlen, jitted):
    """Pure greedy decoding reference: returns (steps,) tokens per seq."""
    from repro.models.transformer import init_cache
    b = toks.shape[0]
    cache = init_cache(cfg, b, maxlen)
    lg, cache = jitted["prefill"](params, cfg, toks, cache)
    out = []
    tok = jnp.argmax(lg, -1)
    for _ in range(steps):
        out.append(tok)
        lg, cache = jitted["decode_step"](params, cfg, cache, tok[:, None])
        tok = jnp.argmax(lg, -1)
    return jnp.stack(out, axis=1)
