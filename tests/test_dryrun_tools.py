"""Unit tests for the dry-run HLO parsing + roofline derivation tools."""
import pytest

from repro.launch.dryrun import parse_collectives, shape_bytes

HLO_SNIPPET = """
HloModule jit_serve_fn
%fused (p0: bf16[8,128]) -> bf16[8,128] {
  ROOT %x = bf16[8,128]{1,0} parameter(0)
}
ENTRY %main {
  %ag = bf16[16,2048]{1,0} all-gather(%p), replica_groups=...
  %ar.1 = f32[4,256]{1,0} all-reduce(%q), to_apply=%add
  %rs = f32[2,128]{1,0} reduce-scatter(%r), dimensions={0}
  %a2a = bf16[8,64,32]{2,1,0} all-to-all(%s), dimensions={0}
  %cp = u32[16]{0} collective-permute(%t), source_target_pairs=...
  %ags = (bf16[4,4]{1,0}, bf16[4,4]{1,0}) all-gather-start(%u)
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert shape_bytes("bf16[16,2048]") == 16 * 2048 * 2
    assert shape_bytes("f32[4,256]") == 4 * 256 * 4
    assert shape_bytes("u32[16]") == 64
    assert shape_bytes("pred[8]") == 8


def test_parse_collectives_kinds_and_bytes():
    r = parse_collectives(HLO_SNIPPET)
    b = r["bytes"]
    assert b["all-gather"] == 16 * 2048 * 2
    assert b["all-reduce"] == 4 * 256 * 4
    assert b["reduce-scatter"] == 2 * 128 * 4
    assert b["all-to-all"] == 8 * 64 * 32 * 2
    assert b["collective-permute"] == 16 * 4
    assert r["counts"]["all-gather"] == 1
    assert r["total_bytes"] == sum(b.values())
    # the dot must not be counted
    assert "dot" not in b


def test_roofline_model_flops_orders():
    from benchmarks.roofline import model_bytes, model_flops
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("llama3-405b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    # train ~ 6ND, prefill ~ 2ND(+attn), decode tiny
    assert tr > pf > dc > 0
    n, d_train = cfg.param_count(), 256 * 4096
    assert abs(tr - 6 * n * d_train) / (6 * n * d_train) < 0.01
    assert model_bytes(cfg, INPUT_SHAPES["decode_32k"]) > \
        cfg.active_param_count() * 2   # weights + KV


def test_roofline_moe_uses_active_params():
    from benchmarks.roofline import model_flops
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    moe = get_config("llama4-maverick-400b-a17b")
    dense = get_config("llama3-405b")
    # similar total size, but MoE decode flops ~ active params only
    f_moe = model_flops(moe, INPUT_SHAPES["decode_32k"])
    f_dense = model_flops(dense, INPUT_SHAPES["decode_32k"])
    assert f_moe < f_dense / 5


def test_analytic_collectives_decode_weight_stationary():
    """Post-optimization decode traffic is activation-scale, not weights."""
    from benchmarks.roofline import analytic_collective_bytes
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("llama3-405b")
    dec = analytic_collective_bytes(cfg, INPUT_SHAPES["decode_32k"], 256)
    assert dec < cfg.param_bytes() / 256        # far below weight movement
    tr = analytic_collective_bytes(cfg, INPUT_SHAPES["train_4k"], 256)
    assert tr > dec                             # train still streams weights
