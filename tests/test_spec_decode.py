"""Speculative decoding: losslessness vs pure greedy decoding; the
acceptance model of Appendix A.1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spec_decode import (acceptance_pmf, expected_generated,
                                    greedy_acceptance, sampled_acceptance,
                                    spec_round)
from repro.models import model as M
from repro.models.transformer import init_cache

from conftest import greedy_reference, tiny_config, tiny_draft_config

TARGETS = {
    "dense": dict(pattern=("attn",)),
    "swa": dict(pattern=("swa",)),
    "hybrid": dict(pattern=("rglru", "rglru", "swa"), arch="hybrid",
                   n_layers=3),
    "rwkv": dict(pattern=("rwkv",), arch="ssm"),
    "moe": dict(pattern=("attn",), arch="moe", n_experts=4, top_k=2,
                moe_dropless=True),
}


@pytest.fixture(scope="module")
def spec_jit():
    return jax.jit(spec_round,
                   static_argnames=("target_cfg", "draft_cfg", "n_cand",
                                    "mesh", "sample"))


@pytest.mark.parametrize("family", list(TARGETS))
def test_spec_decode_lossless(family, jitted, spec_jit):
    kw = dict(TARGETS[family])
    tcfg = tiny_config(kw.pop("pattern"), kw.pop("arch", "dense"),
                       kw.pop("n_layers", None), **kw)
    dcfg = tiny_draft_config(tcfg.vocab_size)
    tp = M.init_params(tcfg, jax.random.PRNGKey(1))
    dp = M.init_params(dcfg, jax.random.PRNGKey(2))
    B, L, T, m = 3, 8, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0,
                              tcfg.vocab_size)
    maxlen = L + T + 3 * (m + 1) + 4

    ref = greedy_reference(tp, tcfg, toks, T, maxlen, jitted)

    tc = init_cache(tcfg, B, maxlen)
    dc = init_cache(dcfg, B, maxlen)
    lg, tc = jitted["prefill"](tp, tcfg, toks, tc)
    _, dc = jitted["prefill"](dp, dcfg, toks, dc)
    t_next = jnp.argmax(lg, -1)
    outs = [[int(t_next[b])] for b in range(B)]
    rounds = 0
    while min(len(o) for o in outs) < T and rounds < 40:
        r = spec_jit(tp, tcfg, tc, dp, dcfg, dc, t_next, m)
        tc, dc, t_next = r["target_cache"], r["draft_cache"], r["t_next"]
        toks_r = np.asarray(r["tokens"])
        for b in range(B):
            for i in range(int(r["n_emitted"][b])):
                outs[b].append(int(toks_r[b, i]))
        rounds += 1
    for b in range(B):
        assert outs[b][:T] == list(np.asarray(ref[b, :T])), family


def test_acceptance_model_matches_simulation():
    """Paper Eq. 10-12: pmf sums to 1 and E[n] matches Monte-Carlo."""
    rng = np.random.default_rng(0)
    for p in (0.0, 0.3, 0.7, 0.95):
        for m in (1, 4, 8):
            pmf = np.asarray(acceptance_pmf(p, m))
            assert abs(pmf.sum() - 1.0) < 1e-6
            e = expected_generated(p, m)
            draws = rng.random((200_000, m)) < p
            prefix = np.cumprod(draws, axis=1).sum(1)
            mc = (prefix + 1).mean()
            assert abs(e - mc) < 0.02, (p, m, e, mc)


def test_expected_generated_monotonic():
    for m in (1, 2, 4, 8):
        es = [expected_generated(p, m) for p in np.linspace(0, 1, 11)]
        assert all(b >= a - 1e-9 for a, b in zip(es, es[1:]))
        assert abs(es[0] - 1.0) < 1e-9
        assert abs(es[-1] - (m + 1)) < 1e-9


def test_greedy_acceptance_rule():
    drafts = jnp.array([[5, 6, 7], [5, 9, 7]])
    V = 12
    tl = jnp.full((2, 4, V), -10.0)
    # target greedy: row0 -> [5,6,7,8] (accept all); row1 -> [5,6,...]
    for b, seq in enumerate([[5, 6, 7, 8], [5, 6, 7, 8]]):
        for i, t in enumerate(seq):
            tl = tl.at[b, i, t].set(10.0)
    a, nxt, nc = greedy_acceptance(drafts, tl)
    assert list(a) == [3, 1]        # row1: d1=5 ok, d2=9 != g1=6
    assert list(nxt) == [8, 6]      # bonus / correction token
    assert list(nc) == [4, 2]


def test_sampled_acceptance_lossless_distribution():
    """When draft == target distribution, acceptance prob is ~1."""
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (64, 4, 16)) * 2
    drafts = jnp.argmax(logits[:, :3], -1)
    a, nxt, nc = sampled_acceptance(drafts, logits[:, :3], logits, key)
    assert float(a.mean()) > 2.0  # nearly all accepted


def test_spec_round_emits_between_1_and_m_plus_1(jitted, spec_jit):
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config(tcfg.vocab_size)
    tp = M.init_params(tcfg, jax.random.PRNGKey(1))
    dp = M.init_params(dcfg, jax.random.PRNGKey(2))
    B, L, m = 4, 6, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0, 61)
    tc = init_cache(tcfg, B, 64)
    dc = init_cache(dcfg, B, 64)
    lg, tc = jitted["prefill"](tp, tcfg, toks, tc)
    _, dc = jitted["prefill"](dp, dcfg, toks, dc)
    r = spec_jit(tp, tcfg, tc, dp, dcfg, dc, jnp.argmax(lg, -1), m)
    ne = np.asarray(r["n_emitted"])
    assert ((ne >= 1) & (ne <= m + 1)).all()
    assert (np.asarray(r["target_cache"]["pos"]) ==
            L + ne).all()
    assert (np.asarray(r["draft_cache"]["pos"]) == L + ne).all()
