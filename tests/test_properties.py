"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import MISTRAL_7B, MIXTRAL_8X7B
from repro.core.placement import plan_placement
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.core.spec_decode import (acceptance_pmf, expected_generated,
                                    greedy_acceptance)
from repro.models.attention import attention_mask, ring_slot_positions
from repro.sim.hardware import ENV1, ENV2

probs = st.floats(0.0, 1.0, allow_nan=False)
cands = st.integers(1, 16)


@given(probs, cands)
@settings(deadline=None)
def test_expected_generated_bounds(p, m):
    e = expected_generated(p, m)
    assert 1.0 - 1e-9 <= e <= m + 1 + 1e-9


@given(probs, cands)
@settings(deadline=None)
def test_pmf_sums_to_one_and_matches_expectation(p, m):
    pmf = np.asarray(acceptance_pmf(p, m))
    assert abs(pmf.sum() - 1.0) < 1e-6
    mean = float((np.arange(1, m + 2) * pmf).sum())
    assert abs(mean - expected_generated(p, m)) < 1e-5


@given(st.integers(0, 1000), st.integers(1, 64))
@settings(deadline=None)
def test_ring_slot_positions_invariants(length, window):
    """Slot j holds the latest logical position ≡ j (mod W) below length."""
    pj = np.asarray(ring_slot_positions(window, length, window))
    j = np.arange(window)
    if length > 0:
        valid = pj >= 0
        assert (pj[valid] % window == j[valid]).all()
        assert (pj <= length - 1).all()
        assert (pj[valid] > length - 1 - window).all()
        # exactly min(length, window) valid slots
        assert valid.sum() == min(length, window)
    else:
        assert (pj < 0).all()


@given(st.integers(1, 12), st.integers(1, 24),
       st.one_of(st.none(), st.integers(1, 8)), st.integers(0, 50))
@settings(deadline=None, max_examples=40)
def test_attention_mask_is_causal_and_windowed(sq, skv, window, offset):
    qp = jnp.arange(sq) + offset
    kp = jnp.arange(skv)
    mask = np.asarray(attention_mask(qp, kp, window))
    for i in range(sq):
        for j in range(skv):
            allowed = mask[i, j] == 0.0
            should = j <= i + offset and (window is None or
                                          j > i + offset - window)
            assert allowed == should


@given(st.integers(1, 6), st.integers(1, 6), st.integers(2, 30),
       st.integers(0, 2 ** 31 - 1))
@settings(deadline=None, max_examples=30)
def test_greedy_acceptance_matches_bruteforce(b, m, vocab, seed):
    rng = np.random.default_rng(seed)
    drafts = jnp.asarray(rng.integers(0, vocab, (b, m)), jnp.int32)
    logits = jnp.asarray(rng.normal(size=(b, m + 1, vocab)), jnp.float32)
    a, nxt, nc = greedy_acceptance(drafts, logits)
    g = np.argmax(np.asarray(logits), -1)
    for i in range(b):
        k = 0
        while k < m and int(drafts[i, k]) == int(g[i, k]):
            k += 1
        assert int(a[i]) == k
        assert int(nxt[i]) == int(g[i, k])
        assert int(nc[i]) == k + 1


@given(st.sampled_from([16, 32, 50, 80, 96]),
       st.sampled_from([32, 64, 128, 192, 256]),
       st.sampled_from([4, 5, 6, 8, 10]),
       st.sampled_from([1, 2, 4, 6, 8]),
       st.floats(0.1, 0.95))
@settings(deadline=None, max_examples=30)
def test_planner_report_invariants(bp, bd, bdr, m, p):
    pl = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
    rep = pl.evaluate(Policy(bp, bd, min(bdr, bd), m),
                      Workload(300, 32, p))
    assert rep.throughput > 0
    assert rep.t_prefill > 0 and rep.t_decode > 0
    assert rep.t_decode >= 2 * max(rep.t_target, rep.t_draft) - 1e-9
    assert 1.0 <= rep.expected_tokens <= m + 1


@given(st.sampled_from(["env1", "env2"]))
@settings(deadline=None, max_examples=4)
def test_placement_respects_capacities(env):
    from repro.sim.hardware import ENVS
    hw = ENVS[env]
    for cfg in (MIXTRAL_8X7B,):
        plan = plan_placement(cfg, MISTRAL_7B, hw)
        assert plan.bytes_in("hbm") <= plan.hbm_capacity
        assert plan.bytes_in("host") <= plan.host_capacity
        # draft model is HBM-resident (the paper's key placement decision)
        assert plan.tier_of("draft/params") == "hbm"
        # double-buffered stream slots exist
        assert plan.tier_of("target/stream_slot0") == "hbm"
        assert plan.tier_of("target/stream_slot1") == "hbm"


@given(st.integers(2, 40), st.integers(1, 4), st.integers(2, 8),
       st.integers(0, 2 ** 31 - 1))
@settings(deadline=None, max_examples=20)
def test_moe_dropless_keeps_every_assignment(n, k, e, seed):
    from repro.models.moe import _capacity, _dispatch
    k = min(k, e)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(
        np.stack([rng.choice(e, size=k, replace=False) for _ in range(n)]),
        jnp.int32)
    x = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    cap = _capacity(n, k, e, float("inf"))
    buf, slot = _dispatch(x, idx, e, cap)
    assert (np.asarray(slot) >= 0).all()      # dropless: nothing dropped
    # every (token, expert) assignment is recoverable from the buffer
    for t in range(n):
        for j in range(k):
            got = np.asarray(buf[int(idx[t, j]), int(slot[t, j])])
            np.testing.assert_allclose(got, np.asarray(x[t]), rtol=1e-6)
