"""Model substrate correctness: incremental decode == full forward, for
every layer family; verify/commit rollback equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.transformer import init_cache

from conftest import tiny_config

FAMILIES = {
    "dense-full": dict(pattern=("attn",)),
    "dense-swa": dict(pattern=("swa",)),
    "local-global": dict(pattern=("swa", "attn"), sliding_window=4),
    "moe": dict(pattern=("attn",), arch="moe", n_experts=4, top_k=2,
                moe_dropless=True),
    "rglru-hybrid": dict(pattern=("rglru", "rglru", "swa"), arch="hybrid",
                         n_layers=3),
    "rwkv": dict(pattern=("rwkv",), arch="ssm"),
}


def _cfg(name):
    kw = dict(FAMILIES[name])
    return tiny_config(kw.pop("pattern"), kw.pop("arch", "dense"),
                       kw.pop("n_layers", None), **kw)


@pytest.mark.parametrize("family", list(FAMILIES))
def test_incremental_matches_full(family, jitted):
    cfg = _cfg(family)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    B, L, T = 2, 10, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + T), 0,
                              cfg.vocab_size)
    full = jitted["forward_train"](p, cfg, {"tokens": toks})
    assert bool(jnp.isfinite(full).all())
    cache = init_cache(cfg, B, L + T + 4)
    lg, cache = jitted["prefill"](p, cfg, toks[:, :L], cache)
    np.testing.assert_allclose(lg, full[:, L - 1], rtol=2e-4, atol=2e-4)
    for t in range(T):
        lg, cache = jitted["decode_step"](p, cfg, cache,
                                          toks[:, L + t:L + t + 1])
        np.testing.assert_allclose(lg, full[:, L + t], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("family", list(FAMILIES))
def test_verify_commit_rollback(family, jitted):
    """Batched multi-token verify + partial commit == sequential decode."""
    cfg = _cfg(family)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    B, L, m = 2, 10, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + m + 3), 0,
                              cfg.vocab_size)
    n_commit = jnp.array([2, 3], jnp.int32)
    nxt = jnp.stack([toks[0, L + 2], toks[1, L + 3]])[:, None]

    cache_a = init_cache(cfg, B, 24)
    _, cache_a = jitted["prefill"](p, cfg, toks[:, :L], cache_a)
    lg_v, cache_a, pend = jitted["decode"](p, cfg, cache_a, toks[:, L:L + m])
    full = jitted["forward_train"](p, cfg, {"tokens": toks})
    np.testing.assert_allclose(lg_v, full[:, L:L + m], rtol=2e-4, atol=2e-4)
    cache_a = jitted["commit"](cfg, cache_a, pend, n_commit, m)

    cache_b = init_cache(cfg, B, 24)
    _, cache_b = jitted["prefill"](p, cfg, toks[:, :L], cache_b)
    for t in range(3):
        _, cache_b, pb = jitted["decode"](p, cfg, cache_b,
                                          toks[:, L + t:L + t + 1])
        cm = (jnp.array([t, t]) < n_commit).astype(jnp.int32)
        cache_b = jitted["commit"](cfg, cache_b, pb, cm, 1)

    assert (cache_a["pos"] == cache_b["pos"]).all()
    lg_a, _ = jitted["decode_step"](p, cfg, cache_a, nxt)
    lg_b, _ = jitted["decode_step"](p, cfg, cache_b, nxt)
    np.testing.assert_allclose(lg_a, lg_b, rtol=2e-4, atol=2e-4)


def test_encdec_incremental(jitted):
    cfg = ModelConfig(name="w", arch_type="audio", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=61,
                      use_rope=False, norm="layernorm", activation="gelu",
                      encoder_decoder=True, n_encoder_layers=2,
                      encoder_len=12, dtype="float32", remat=False)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    B, L, T = 2, 6, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + T), 0, 61)
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 12, 64))
    full = M.forward_train(p, cfg, {"tokens": toks, "encoder_frames": frames})
    cache = init_cache(cfg, B, L + T + 2)
    lg, cache = M.prefill(p, cfg, toks[:, :L], cache, encoder_frames=frames)
    np.testing.assert_allclose(lg, full[:, L - 1], rtol=2e-4, atol=2e-4)
    for t in range(T):
        lg, cache = jitted["decode_step"](p, cfg, cache,
                                          toks[:, L + t:L + t + 1])
        np.testing.assert_allclose(lg, full[:, L + t], rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_counted():
    """Capacity-based dispatch drops overflow tokens deterministically."""
    from repro.models.moe import _capacity, _dispatch, _route, init_moe
    p = init_moe(jax.random.PRNGKey(0), 16, 32, 4, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    idx, gate = _route(p["router"], x, 4, 2)
    cap = _capacity(32, 2, 4, 1.0)
    buf, slot = _dispatch(x, idx, 4, cap)
    assert buf.shape == (4, cap, 16)
    assert (slot < cap).all()
    # dropless capacity covers everything
    assert _capacity(32, 2, 4, float("inf")) == 32


def test_param_count_formula():
    """param_count matches the actual initialized tree."""
    for fam in ("dense-full", "moe", "rglru-hybrid", "rwkv"):
        cfg = _cfg(fam)
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(p))
        approx = cfg.param_count()
        assert abs(actual - approx) / actual < 0.15, (fam, actual, approx)
