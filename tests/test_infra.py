"""Infrastructure tests: offload engine, checkpointing, data pipeline,
launch helpers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.transformer import init_cache

from conftest import tiny_config


def test_offloaded_model_matches_resident(jitted, tmp_path):
    """Host-streamed execution == device-resident execution."""
    from repro.core.offload import (OffloadedModel, host_memory_kind,
                                    put_host)
    cfg = tiny_config(("attn",))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 61)

    cache_a = init_cache(cfg, 2, 24)
    lg_ref, cache_a = jitted["prefill"](params, cfg, toks, cache_a)
    nxt = jnp.argmax(lg_ref, -1)[:, None]
    ref, _ = jitted["decode_step"](params, cfg, cache_a, nxt)

    om = OffloadedModel(cfg, params)
    assert om.streamed_bytes() > 0
    # layers live in the host tier at rest ('pinned_host' where the
    # backend exposes it; the backend default space otherwise)
    leaf = jax.tree.leaves(om.layers_host)[0]
    assert leaf.sharding.memory_kind == host_memory_kind()
    cache_b = init_cache(cfg, 2, 24)
    lg_b, cache_b = om.prefill(toks, cache_b)
    np.testing.assert_allclose(lg_b, lg_ref, rtol=1e-5, atol=1e-5)
    lg2, cache_b, pend = om.decode(cache_b, nxt)
    cache_b = M.commit(cfg, cache_b, pend, jnp.ones((2,), jnp.int32), 1)
    np.testing.assert_allclose(lg2[:, 0], ref, rtol=1e-5, atol=1e-5)


def test_host_attention_matches_device():
    from repro.core.offload import host_attention_direct
    from repro.models.attention import attention_direct
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 3, 4, 16))
    k = jax.random.normal(k2, (2, 10, 2, 16))
    v = jax.random.normal(k3, (2, 10, 2, 16))
    mask = jnp.zeros((3, 10))
    a = jax.jit(lambda *x: host_attention_direct(*x, 0.25))(q, k, v, mask)
    b = attention_direct(q, k, v, mask, 0.25)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoint import restore_checkpoint, save_checkpoint
    cfg = tiny_config(("rglru", "rglru", "swa"), "hybrid", 3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    path = tmp_path / "ckpt.msgpack"
    save_checkpoint(path, params, step=42)
    like = M.init_params(cfg, jax.random.PRNGKey(1))
    restored, step = restore_checkpoint(path, like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dataset_statistics_match_paper_table2():
    from repro.data.pipeline import DATASET_STATS, synthetic_dataset
    ds = synthetic_dataset("summeval", n_prompts=512)
    lens = np.array([len(p) for p in ds.prompts])
    assert abs(lens.mean() - DATASET_STATS["summeval"]["s_avg"]) < 40
    assert lens.max() <= DATASET_STATS["summeval"]["s_max"]


def test_pad_batch_left_pads():
    from repro.data.pipeline import pad_batch
    out = pad_batch([np.array([1, 2, 3]), np.array([9])])
    assert out.shape == (2, 3)
    assert out[1, -1] == 9 and out[1, 0] == 0


def test_mesh_helpers():
    from repro.launch.mesh import batch_axes, make_host_mesh
    m = make_host_mesh()
    assert set(m.axis_names) == {"data", "model"}
    assert batch_axes(m) == ("data",)


def test_spec_applicability_policy():
    from repro.configs import ARCHS
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.specs import applicable
    long = INPUT_SHAPES["long_500k"]
    runs = [a for a, c in ARCHS.items() if applicable(c, long)[0]]
    assert sorted(runs) == ["gemma3-12b", "recurrentgemma-2b", "rwkv6-7b"]
    for a, c in ARCHS.items():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert applicable(c, INPUT_SHAPES[s])[0], (a, s)
