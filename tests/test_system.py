"""System-level behaviour: the full SpecOffloadEngine, the serving engine,
the planner/simulator against paper claims, training convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MISTRAL_7B, MIXTRAL_8X7B, MIXTRAL_8X22B
from repro.core.pipeline import SpecOffloadEngine
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.models import model as M
from repro.sim.hardware import ENV1, ENV2
from repro.sim.simulator import ablation, disk_mode, end_to_end

from conftest import greedy_reference, tiny_config, tiny_draft_config


def test_engine_end_to_end_lossless(jitted):
    """The dual-batch interleaved engine == pure greedy decoding."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    eng = SpecOffloadEngine(tcfg, dcfg)
    eng.init_from_seed(0)
    B, L, G = 4, 8, 10
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0, 61)
    res = eng.generate(prompts, gen_len=G, n_cand=3)
    ref = greedy_reference(eng.tp, tcfg, prompts, G, 64, jitted)
    assert (res.tokens == np.asarray(ref)).all()
    assert res.rounds > 0


def test_serving_engine_drains_queue():
    from repro.serving.engine import ServeRequest, ServingEngine
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg, n_cand=2, batch_size=2)
    se.init_from_seed(0)
    rng = np.random.default_rng(0)
    for i in range(5):  # deliberately not a multiple of the wave size
        se.submit(ServeRequest(i, rng.integers(0, 61, 8).astype(np.int32),
                               max_new_tokens=4))
    done = se.run()
    assert len(done) == 5
    assert all(len(r.result) == 4 for r in done)
    assert se.pending() == 0


def test_training_learns():
    from repro.data.pipeline import make_lm_batches
    from repro.training.optimizer import make_optimizer
    from repro.training.train_loop import train_loop
    cfg = tiny_config(("attn",), vocab_size=101)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    oi, _ = make_optimizer("adamw")
    data = make_lm_batches(4, 32, cfg.vocab_size)
    _, _, log = train_loop(cfg, params, oi(params), data, 40, lr=3e-3,
                           log_every=39)
    assert log[-1]["loss"] < log[0]["loss"] * 0.7


# ---------------------------------------------------------------------------
# paper-claim regression gates (simulator)


def test_fig5_reproduction_within_tolerance():
    res = end_to_end(MIXTRAL_8X7B, MISTRAL_7B, ENV1, Workload(503, 48, .75),
                     Policy(80, 192, 8, 8))
    spec = res["specoffload"].throughput
    assert abs(spec - 24.74) / 24.74 < 0.20
    assert abs(res["flexgen"].throughput - 9.74) / 9.74 < 0.20
    best = max(r.throughput for k, r in res.items() if k != "specoffload")
    assert 2.0 < spec / best < 3.2          # paper: 2.53x


def test_fig6_utilization_reproduction():
    res = end_to_end(MIXTRAL_8X7B, MISTRAL_7B, ENV1, Workload(503, 48, .75),
                     Policy(80, 192, 8, 8))
    assert abs(res["specoffload"].gpu_util - 0.5867) < 0.12
    ratio = res["specoffload"].gpu_util / res["flexgen"].gpu_util
    assert 3.5 < ratio < 7.0                # paper: 4.49x


def test_table4_ablation_ordering():
    ab = ablation(MIXTRAL_8X7B, MISTRAL_7B, ENV1, Workload(503, 48, .75),
                  Policy(80, 192, 8, 8), Policy(50, 256, 5, 2))
    assert ab["all"].throughput > ab["no_policy"].throughput
    assert ab["all"].throughput > ab["serial_sd"].throughput
    assert ab["all"].throughput > ab["no_sd"].throughput
    assert ab["serial_sd"].throughput > ab["no_sd"].throughput


def test_fig8_disk_ratio():
    dm = disk_mode(MIXTRAL_8X22B, MISTRAL_7B, ENV1, Workload(503, 48, .75),
                   Policy(16, 64, 8, 8))
    assert 0.2 < dm["ratio"] < 0.5          # paper: 0.293


def test_planner_search_beats_bad_policy():
    pl = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
    wl = Workload(503, 48, 0.75)
    best = pl.search(wl)
    bad = pl.evaluate(Policy(50, 256, 5, 2), wl)
    assert best.throughput > bad.throughput
    assert best.feasible


# ---------------------------------------------------------------------------
# dry-run artifact gates


def test_dryrun_records_complete_and_compiled():
    from benchmarks.roofline import full_table, load_records
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        if not recs:
            pytest.skip("dry-run artifacts not generated yet")
        assert len(recs) == 40, f"{mesh}: {len(recs)} records"
        ok = [r for r in recs if r.get("status") == "ok"]
        skip = [r for r in recs if r.get("status") == "skip"]
        assert len(ok) == 33 and len(skip) == 7, (len(ok), len(skip))
        for r in skip:
            assert "long-context" in r["reason"]


def test_roofline_terms_positive_and_bounded():
    from benchmarks.roofline import full_table
    rows = [r for r in full_table("single") if r["dominant"] != "SKIP"]
    if not rows:
        pytest.skip("dry-run artifacts not generated yet")
    for r in rows:
        assert r["t_compute_s"] >= 0 and r["t_memory_s"] > 0
        assert r["t_collective_s"] >= 0
        assert r["dominant"] in ("compute", "memory", "collective")
