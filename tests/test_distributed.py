"""Distributed-correctness tests (run in subprocesses with 8 fake devices,
since the main pytest process holds the 1-device CPU backend)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "HOME": "/root"},
                       timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_moe_distributed_modes_match_local():
    out = _run("""
        from repro.models.moe import (apply_moe, init_moe, _moe_local,
                                      select_moe_mode)
        from repro.launch.mesh import activate_mesh, make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        E, D, F, topk = 8, 64, 96, 2
        p = init_moe(jax.random.PRNGKey(0), D, F, E, "swiglu", jnp.float32)
        for b, s, expect in [(4, 8, "ep"), (6, 1, "ep_psum")]:
            x = jax.random.normal(jax.random.PRNGKey(1), (b, s, D))
            ref = _moe_local(p, x.reshape(-1, D), n_experts=E, top_k=topk,
                             capacity_factor=float("inf"),
                             activation="swiglu").reshape(b, s, D)
            with activate_mesh(mesh):
                mode = select_moe_mode(E, s, mesh)
                assert mode == expect, (mode, expect)
                out = jax.jit(lambda pp, xx: apply_moe(
                    pp, xx, n_experts=E, top_k=topk, activation="swiglu",
                    mesh=mesh, capacity_factor=float("inf")))(p, x)
            err = float(jnp.abs(out - ref).max())
            assert err < 1e-5, (mode, err)
        print("MOE_OK")
    """)
    assert "MOE_OK" in out


def test_sharded_decode_matches_single_device():
    """decode_step under a (2,4) mesh == decode_step on one device,
    including the weight-stationary decode hints."""
    out = _run("""
        from repro.configs.base import ModelConfig
        from repro.models import model as M
        from repro.models.transformer import init_cache
        cfg = ModelConfig(name="t", arch_type="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=97, dtype="float32", remat=False)
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 97)
        cache = init_cache(cfg, 4, 24)
        lg, cache = M.prefill(p, cfg, toks, cache)
        nxt = jnp.argmax(lg, -1)[:, None]
        ref, _ = M.decode_step(p, cfg, cache, nxt)

        from repro.launch.mesh import activate_mesh, make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with activate_mesh(mesh):
            lg2, cache2 = jax.jit(M.prefill, static_argnums=(1,))(
                p, cfg, toks, init_cache(cfg, 4, 24))
            got, _ = jax.jit(M.decode_step, static_argnums=(1,))(
                p, cfg, cache2, nxt)
        err = float(jnp.abs(ref - got).max())
        assert err < 1e-4, err
        print("DECODE_OK")
    """)
    assert "DECODE_OK" in out


def test_train_step_runs_under_mesh():
    """One real (tiny) train step executes under the production-style mesh
    with the sequence-parallel profile + grad accumulation."""
    out = _run("""
        from repro.configs.base import ModelConfig
        from repro.models import model as M
        from repro.models.layers import sequence_sharding
        from repro.training.optimizer import make_optimizer
        from repro.training.train_loop import make_train_step
        cfg = ModelConfig(name="t", arch_type="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=97, dtype="float32", remat=True)
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        oi, _ = make_optimizer("adamw")
        st = oi(p)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 32), 0, 97)}
        from repro.launch.mesh import activate_mesh, make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        step = make_train_step(cfg, mesh, 1e-3, accum_steps=2)
        with activate_mesh(mesh):
            def fn(pp, ss, bb):
                with sequence_sharding("model"):
                    return step(pp, ss, bb)
            p2, st2, loss = jax.jit(fn)(p, st, batch)
        assert bool(jnp.isfinite(loss)), loss
        print("TRAIN_OK", float(loss))
    """)
    assert "TRAIN_OK" in out
