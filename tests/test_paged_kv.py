"""Paged KV substrate: allocator unit behavior, engine losslessness under
mid-flight admission, int8 cold blocks, prefix sharing, block pressure."""
import dataclasses

import numpy as np
import pytest

from repro.serving.engine import (SchedulerConfig, ServeRequest,
                                  ServingEngine)
from repro.serving.paged_kv import BlockAllocator, prefix_block_keys

from conftest import greedy_reference, tiny_config, tiny_draft_config


# ---------------------------------------------------------------------------
# allocator unit tests (pure host-side, no jax)


def test_allocator_alloc_free_cycle():
    a = BlockAllocator(8)                   # 7 grantable (block 0 reserved)
    got = a.alloc(3)
    assert len(got) == 3 and 0 not in got
    assert a.used == 3 and a.peak_used == 3
    for bid in got:
        a.decref(bid)
    assert a.used == 0 and a.can_alloc(7)
    assert not a.can_alloc(8)
    with pytest.raises(RuntimeError):
        a.alloc(8)


def test_allocator_refcounted_sharing():
    a = BlockAllocator(8)
    (bid,) = a.alloc(1)
    a.incref(bid)
    a.decref(bid)
    assert a.used == 1                      # still referenced once
    a.decref(bid)
    assert a.used == 0


def test_allocator_prefix_cache_and_eviction():
    a = BlockAllocator(4)                   # 3 grantable
    b1, b2 = a.alloc(2)
    a.register(b1, b"k1")
    a.register(b2, b"k2")
    a.decref(b1)
    a.decref(b2)
    # hashed blocks park in the cached tier, resurrectable by key
    assert a.used == 0 and a.cached == 2
    assert a.lookup(b"k1") == b1 and a.prefix_hits == 1
    # allocation pressure evicts the remaining (LRU) cached block
    fresh = a.alloc(2)
    assert a.evictions == 1 and b2 in fresh
    assert a.lookup(b"k2") is None          # evicted: key is gone
    assert a.lookup(b"k1") == b1            # live block still shareable


def test_prefix_block_keys_chain():
    p1 = np.arange(40, dtype=np.int32)
    p2 = np.concatenate([np.arange(32, dtype=np.int32),
                         np.arange(100, 108, dtype=np.int32)])
    k1 = prefix_block_keys(p1, 16)
    k2 = prefix_block_keys(p2, 16)
    assert len(k1) == len(k2) == 2          # full blocks only (40//16 == 2)
    assert k1[0] == k2[0] and k1[1] == k2[1]
    # chaining: same chunk at a different depth gets a different key
    k3 = prefix_block_keys(np.concatenate([p1[16:32], p1[:16]]), 16)
    assert k3[0] != k1[0] and k3[1] != k1[1]


# ---------------------------------------------------------------------------
# engine integration


def _mk_engine(tcfg=None, **cfg_kw):
    tcfg = tcfg or tiny_config(("attn",))
    se = ServingEngine(tcfg, tiny_draft_config(),
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              **cfg_kw))
    se.init_from_seed(0)
    return se


def _assert_lossless(se, reqs, jitted, cfg=None, maxlen=96):
    cfg = cfg or se.target_cfg
    for r in reqs:
        ref = greedy_reference(se.engine.tp, cfg,
                               np.asarray(r.prompt)[None, :],
                               r.max_new_tokens, maxlen, jitted)
        assert (np.asarray(ref)[0] == r.result).all(), f"rid {r.rid}"


def test_paged_lossless_midflight_admission(jitted):
    """Paged decode under retirement + mid-flight admission stays
    token-identical to a target-only greedy decode per sequence."""
    se = _mk_engine()
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):                      # 6 reqs > 4 slots: forced churn
        p = rng.integers(0, 61, int(rng.integers(5, 13))).astype(np.int32)
        reqs.append(ServeRequest(i, p, max_new_tokens=int(
            rng.integers(3, 10))))
        se.submit(reqs[-1])
    done = se.run()
    assert len(done) == 6 and se.pending() == 0
    assert se.stats()["fused_compiles"] == 1
    _assert_lossless(se, reqs, jitted)
    kv = se.kv_stats()
    assert kv["paged"] and kv["peak_blocks_in_use"] > 0
    # retirements must return blocks: nothing is live at drain
    assert all(a["used"] == 0 for a in kv["allocators"])


def test_paged_lossless_mixed_layer_pattern(jitted):
    """SWA ring layers stay contiguous next to the paged ATTN pool."""
    se = _mk_engine(tcfg=tiny_config(("swa", "attn")))
    rng = np.random.default_rng(2)
    reqs = [ServeRequest(i, rng.integers(0, 61, 8).astype(np.int32), 5)
            for i in range(3)]
    for r in reqs:
        se.submit(r)
    assert len(se.run()) == 3
    _assert_lossless(se, reqs, jitted)


def test_paged_quantized_cold_blocks(jitted):
    """int8 pool (quantize-on-write) is token-identical to a contiguous
    greedy decode with the int8 KV cache — the promoted numerics of
    tests/test_kv_quant.py."""
    se = _mk_engine(kv_quant_cold=True)
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(i, rng.integers(0, 61, 9).astype(np.int32), 6)
            for i in range(3)]
    for r in reqs:
        se.submit(r)
    assert len(se.run()) == 3
    int8_cfg = dataclasses.replace(se.target_cfg, kv_cache_dtype="int8")
    _assert_lossless(se, reqs, jitted, cfg=int8_cfg)
    kv = se.kv_stats()
    # int8 pool: 1-byte values + f32 scales instead of 4-byte f32 values
    f32_block = (2 * se.target_cfg.n_layers * se.target_cfg.n_kv_heads
                 * se.target_cfg.head_dim * 4 * se.config.block_size)
    assert kv["bytes_per_block"] < f32_block


def test_prefix_cache_shares_blocks(jitted):
    """Two tenants with a common block-aligned system prompt share its
    pool blocks — fewer fresh allocations — with identical outputs."""
    se = _mk_engine()
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, 61, 32).astype(np.int32)
    p1 = np.concatenate([sys_prompt,
                         rng.integers(0, 61, 5).astype(np.int32)])
    p2 = np.concatenate([sys_prompt,
                         rng.integers(0, 61, 7).astype(np.int32)])
    r1, r2 = ServeRequest(0, p1, 5), ServeRequest(1, p2, 5)
    se.submit(r1)
    se.submit(r2)
    assert len(se.run()) == 2
    kv = se.kv_stats()
    n_shared_expected = len(sys_prompt) // se.config.block_size
    assert kv["prefix_hits"] == n_shared_expected
    # both tenants landed in one half; its allocator granted the shared
    # blocks once and reused them for the second tenant
    alloc = next(a for a in kv["allocators"] if a["granted_total"])
    fresh = alloc["granted_total"] - alloc["prefix_hits"]
    blocks = lambda L, g: -(-(L + g + 3 * 3 + 4) // se.config.block_size)
    assert fresh == blocks(len(p1), 5) + blocks(len(p2), 5) \
        - n_shared_expected
    _assert_lossless(se, [r1, r2], jitted)


def test_block_pressure_queues_instead_of_crashing(jitted):
    """A pool that fits one sequence at a time: admission stalls under
    pressure, requests complete as retirements free blocks, outputs stay
    exact (regression for the prompt-exceeds-free-blocks crash)."""
    se = _mk_engine(num_blocks=4, max_len=48, prefix_cache=False)
    rng = np.random.default_rng(4)
    reqs = [ServeRequest(i, rng.integers(0, 61, 10).astype(np.int32), 5)
            for i in range(4)]
    for r in reqs:
        se.submit(r)
    done = se.run()
    assert len(done) == 4 and se.pending() == 0
    assert sum(r.queue_s > 0 for r in reqs) >= 2
    kv = se.kv_stats()
    assert kv["peak_blocks_in_use"] <= 2 * 3   # never both halves full
    _assert_lossless(se, reqs, jitted)


def test_submit_rejects_never_fitting_request():
    """A reservation beyond the block pool is refused gracefully —
    submit() returns False and stamps the reason instead of raising."""
    se = _mk_engine(num_blocks=4, max_len=48)
    big = ServeRequest(0, np.zeros(40, np.int32), 8)
    assert se.submit(big) is False
    assert big.rejected == "never_fits"
    assert se.pending() == 0 and se.rejected_total == 1


def test_kv_bytes_per_seq_feeds_planner():
    se = _mk_engine(replan_threshold=0.2, replan_interval=2)
    rng = np.random.default_rng(5)
    se.submit(ServeRequest(0, rng.integers(0, 61, 8).astype(np.int32), 8))
    se.run()
    kvb = se._kv_bytes_per_seq()
    assert kvb is not None and kvb > 0
    # block-granular: a whole number of blocks per admitted sequence
    assert kvb % se.kv_stats()["bytes_per_block"] == 0
