"""Request-scoped observability: per-request timelines + Chrome tracks,
SLO monitor, anomaly-triggered flight recorder, and the bench_compare
regression gate.

The load-bearing guarantees:

* tracking is host-side only — traced and untraced runs stay
  token-identical with exactly one fused compile;
* per-request decode spans land inside the engine's round spans (the
  request view and PR 7's bubble view describe the same pipeline);
* a tight TTFT SLO on a two-tenant open-loop trace dumps exactly ONE
  schema-valid postmortem bundle (cooldown collapses the storm);
* bench_compare passes on the committed baseline and fails on a
  synthetically regressed digest.
"""
import asyncio
import json
import os

import numpy as np
import pytest

from repro.obs import NULL_REQUEST_TRACKER, SLO, FlightRecorder
from repro.obs.request_trace import (RequestTracker, inter_token_gaps,
                                     percentile_of, timelines_summary)
from repro.obs.schema import (validate_postmortem_bundle,
                              validate_request_timeline)
from repro.obs.slo import SLOMonitor, as_slos
from repro.serving.engine import SchedulerConfig, ServeRequest, ServingEngine

from conftest import tiny_config, tiny_draft_config


def _requests(n, seed=0, gen=(3, 8)):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        p = rng.integers(0, 61, int(rng.integers(5, 13))).astype(np.int32)
        out.append(ServeRequest(i, p,
                                max_new_tokens=int(rng.integers(*gen)),
                                tenant="acme" if i % 2 else "beta"))
    return out


def _engine(**cfg_kw):
    se = ServingEngine(tiny_config(("attn",)), tiny_draft_config(),
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              **cfg_kw))
    se.init_from_seed(0)
    return se


@pytest.fixture(scope="module")
def tracked():
    """One run with request timelines + span tracer, shared below."""
    se = _engine(request_timeline=True, trace=True)
    for r in _requests(5):
        se.submit(r)
    done = se.run()
    return se, done


# ---------------------------------------------------------------------------
# timelines: schema, phase accounting, per-request Chrome tracks


def test_timelines_validate_and_cover_every_request(tracked):
    se, done = tracked
    tls = se.request_timelines()
    assert len(tls) == len(done) == 5
    for tl in tls:
        assert validate_request_timeline(tl) == []
    by_rid = {tl["rid"]: tl for tl in tls}
    for r in done:
        tl = by_rid[r.rid]
        assert tl["tokens"] == len(r.result)
        assert tl["tenant"] == r.tenant
        assert tl["rejected"] is None
        # verify rounds alone can't exceed total decode attribution
        assert (sum(p["dur_s"] for p in tl["per_round"])
                <= tl["decode_s"] + 1e-9)
        assert tl["queue_s"] >= 0 and tl["stall_s"] >= 0
        p99 = tl["inter_token_p99_s"]
        assert p99 is None or p99 >= 0.0


def test_per_request_tracks_in_chrome_trace(tracked):
    se, done = tracked
    trace = se.chrome_trace()
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"}
    for r in done:
        assert f"req:{r.rid}" in names, f"missing req:{r.rid} track"
    # every request shows queue, prefill and at least one decode span
    tids = {e["args"]["name"]: e["tid"] for e in trace["traceEvents"]
            if e.get("ph") == "M" and e["name"] == "thread_name"}
    for r in done:
        spans = [e["name"] for e in trace["traceEvents"]
                 if e.get("ph") == "X" and e["tid"] == tids[f"req:{r.rid}"]]
        assert "queue" in spans and "prefill" in spans
        assert "verify" in spans


def test_request_decode_spans_inside_round_spans(tracked):
    """The request view and the bubble/round view describe one pipeline:
    each per-request verify span must lie inside some round span."""
    se, _ = tracked
    evs = se.chrome_trace()["traceEvents"]
    tids = {e["tid"]: e["args"]["name"] for e in evs
            if e.get("ph") == "M" and e["name"] == "thread_name"}
    rounds = [(e["ts"], e["ts"] + e["dur"]) for e in evs
              if e.get("ph") == "X" and tids[e["tid"]] == "round"
              and e["name"] == "round"]
    verify = [(e["ts"], e["ts"] + e["dur"]) for e in evs
              if e.get("ph") == "X" and e.get("cat") == "request"
              and e["name"] == "verify"]
    assert rounds and verify
    tol = 1e3   # us
    for v0, v1 in verify:
        assert any(r0 - tol <= v0 and v1 <= r1 + tol
                   for r0, r1 in rounds), "verify span outside all rounds"


def test_timelines_summary_aggregates(tracked):
    se, done = tracked
    s = timelines_summary(se.request_timelines())
    assert s["requests"] == len(done)
    assert s["tokens"] == sum(len(r.result) for r in done)
    assert s["decode_s_total"] > 0.0


# ---------------------------------------------------------------------------
# parity: tracking must never perturb the engine


def test_token_parity_and_one_compile_traced_vs_untraced(tracked):
    se, done = tracked
    assert se.stats()["fused_compiles"] == 1
    plain = _engine()                     # metrics only, no tracking
    assert plain.requests is NULL_REQUEST_TRACKER
    for r in _requests(5):
        plain.submit(r)
    plain_done = plain.run()
    assert plain.stats()["fused_compiles"] == 1
    assert plain.request_timelines() == []
    traced_by_rid = {r.rid: list(map(int, r.result)) for r in done}
    for r in plain_done:
        assert list(map(int, r.result)) == traced_by_rid[r.rid]


# ---------------------------------------------------------------------------
# SLOs: scoping, monitor, violation -> exactly one postmortem bundle


def test_slo_scoping_and_normalization():
    slo = SLO("gold_ttft", "ttft_s", 0.5, tenant="acme", priority=0)
    assert slo.applies("acme", 0) and not slo.applies("acme", 1)
    assert not slo.applies("beta", 0)
    every = SLO("any", "e2e_s", 1.0)
    assert every.applies("x", 9)
    norm = as_slos([{"name": "n", "metric": "queue_s",
                     "threshold_s": 2.0}, every])
    assert norm[0].metric == "queue_s" and norm[1] is every
    with pytest.raises(ValueError):
        SLO("bad", "nope_s", 1.0)


def test_slo_monitor_compliance_counts():
    mon = SLOMonitor([SLO("ttft", "ttft_s", 0.5)])
    good = ServeRequest(0, np.zeros(1, np.int32), arrival_s=0.0)
    good.first_token_s = 0.2
    bad = ServeRequest(1, np.zeros(1, np.int32), arrival_s=0.0)
    bad.first_token_s = 3.0
    mon.observe_ttft(good)
    mon.observe_ttft(bad)
    rep = mon.report()
    assert rep["violations"] == 1
    c = rep["compliance"]["ttft/default"]
    assert c["evaluated"] == 2 and c["compliance"] == 0.5
    assert mon.violations[0]["rid"] == 1


def test_tight_ttft_slo_dumps_exactly_one_valid_bundle(tmp_path):
    """Two-tenant open-loop trace through the asyncio front door with an
    unmeetable TTFT objective: every request violates, the cooldown
    collapses the storm into exactly one schema-valid bundle."""
    from repro.serving.server import AsyncServingServer

    out_dir = os.environ.get("REPRO_POSTMORTEM_DIR") or str(tmp_path)
    se = ServingEngine(tiny_config(("attn",)), tiny_draft_config(),
                       config=SchedulerConfig(
                           max_batch=2, n_cand=2, clock="real", qos=True,
                           max_len=64, request_timeline=True,
                           slos=({"name": "tight_ttft",
                                  "metric": "ttft_s",
                                  "threshold_s": 1e-9},),
                           postmortem_dir=out_dir))
    se.init_from_seed(0)
    rng = np.random.default_rng(1)

    async def drive():
        async with AsyncServingServer(se, max_queue=8) as srv:
            handles = []
            for i in range(4):
                p = rng.integers(0, 61, 6).astype(np.int32)
                handles.append(await srv.submit(
                    p, max_new_tokens=4,
                    tenant="acme" if i % 2 else "beta"))
            return [await srv.collect(h) for h in handles]

    streams = asyncio.run(drive())
    assert all(len(s) > 0 for s in streams)
    rep = se.slo_report()
    assert rep["violations"] == 4                  # every request missed
    assert {k.split("/")[1] for k in rep["compliance"]} == {"acme", "beta"}
    bundles = [p for p in se.recorder.bundles
               if os.path.basename(p).endswith("slo_tight_ttft")]
    assert len(se.recorder.bundles) == len(bundles) == 1
    assert validate_postmortem_bundle(bundles[0]) == []
    with open(os.path.join(bundles[0], "manifest.json")) as f:
        man = json.load(f)
    assert man["reason"] == "slo_tight_ttft"
    with open(os.path.join(bundles[0], "config.json")) as f:
        cfg = json.load(f)
    assert cfg["slos"][0]["name"] == "tight_ttft"
    # stream deliveries landed on the timelines
    tls = se.request_timelines()
    assert sum(tl["deliveries"] for tl in tls) == sum(
        len(s) for s in streams)


def test_bundle_tampering_detected(tmp_path):
    rec = FlightRecorder(capacity=8, out_dir=str(tmp_path),
                         cooldown_s=0.0)
    rec.record_round({"round": 0, "t0": 1.0, "t1": 1.5})
    rec.record_instant("spike", {"depth": 9})
    path = rec.trigger("unit", {}, metrics={}, engine={
        "rounds": 1, "tokens_out": 0, "queue_depth": 9}, config={})
    assert path is not None and validate_postmortem_bundle(path) == []
    man_p = os.path.join(path, "manifest.json")
    with open(man_p) as f:
        man = json.load(f)
    man["schema"] = "bogus/v0"
    with open(man_p, "w") as f:
        json.dump(man, f)
    assert any("schema" in p for p in validate_postmortem_bundle(path))
    os.remove(os.path.join(path, "engine.json"))
    assert any("engine.json" in p
               for p in validate_postmortem_bundle(path))


# ---------------------------------------------------------------------------
# flight recorder: anomaly detectors, cooldown, bundle cap


def test_recorder_accept_collapse_and_queue_spike():
    rec = FlightRecorder(warmup=4)
    for _ in range(10):
        assert rec.check(accept_mean=0.8, queue_depth=1) is None
    hit = rec.check(accept_mean=0.05, queue_depth=1)
    assert hit is not None and hit[0] == "accept_collapse"
    rec2 = FlightRecorder(warmup=4)
    for _ in range(10):
        assert rec2.check(busy_frac=0.9, queue_depth=2) is None
    hit = rec2.check(busy_frac=0.9, queue_depth=40)
    assert hit is not None and hit[0] == "queue_spike"
    hit = rec2.check(busy_frac=0.1, queue_depth=2)
    assert hit is not None and hit[0] == "busy_drop"


def test_recorder_warmup_suppresses_detectors():
    rec = FlightRecorder(warmup=50)
    for _ in range(10):
        rec.check(accept_mean=0.8)
    assert rec.check(accept_mean=0.01) is None   # still warming up


def test_recorder_cooldown_and_cap(tmp_path):
    rec = FlightRecorder(out_dir=str(tmp_path), cooldown_s=3600.0)
    assert rec.trigger("a", metrics={}, engine={}, config={}) is not None
    assert rec.trigger("b", metrics={}, engine={}, config={}) is None
    assert len(rec.triggers) == 2 and len(rec.bundles) == 1
    capped = FlightRecorder(out_dir=str(tmp_path / "cap"),
                            cooldown_s=0.0, max_bundles=2)
    dumped = [capped.trigger(f"r{i}", metrics={}, engine={}, config={})
              for i in range(5)]
    assert sum(1 for p in dumped if p) == 2


def test_recorder_no_dir_never_touches_disk():
    rec = FlightRecorder(out_dir=None, cooldown_s=0.0)
    sentinel = []
    assert rec.trigger("x", metrics=lambda: sentinel.append(1)) is None
    assert rec.triggers and rec.bundles == [] and sentinel == []


# ---------------------------------------------------------------------------
# tracker units: inter-token cadence, delivery counting, disabled mode


def test_inter_token_gaps_and_percentile():
    rounds = [{"emitted": 2, "t1": 1.0}, {"emitted": 0, "t1": 1.5},
              {"emitted": 1, "t1": 2.0}, {"emitted": 3, "t1": 2.1}]
    gaps = inter_token_gaps(rounds)
    # r0: 2 tokens -> one zero gap; r2 first token 1.0s after r0; r3
    # first token 0.1s later plus two zero gaps
    assert gaps == [0.0, 1.0, pytest.approx(0.1), 0.0, 0.0]
    assert percentile_of(gaps, 99) == pytest.approx(1.0)
    assert percentile_of([5.0], 50) == 5.0
    assert np.isnan(percentile_of([], 50))


def test_tracker_preemption_accounting():
    tr = RequestTracker()
    req = ServeRequest(7, np.zeros(3, np.int32), max_new_tokens=8,
                       tenant="t")
    tr.on_submit(req, wall=0.0)
    tr.on_admit(req, 1.0, 1.25)              # queued 1s, prefill .25s
    req.first_token_s = 0.0                  # first token produced
    tr.on_round(req, 0, 1.3, 1.6, accepted=1, emitted=2)
    tr.on_preempt(req, wall=2.0)
    tr.on_admit(req, 3.0, 3.5, resumed=True)  # parked 1s, prefill .5s
    tr.on_round(req, 5, 3.6, 3.9, accepted=0, emitted=1, role="verify")
    tr.on_round(req, 6, 4.0, 4.2, role="draft")
    req.result = np.zeros(3, np.int32)
    tr.on_finish(req, wall=4.5)
    tl = tr.timeline(7)
    assert validate_request_timeline(tl) == []
    assert tl["queue_s"] == pytest.approx(1.0)
    assert tl["preempted_s"] == pytest.approx(1.0)
    assert tl["preemptions"] == 1
    assert tl["prefill_s"] == pytest.approx(0.75)
    assert tl["decode_s"] == pytest.approx(0.8)   # .3 + .3 + .2 (draft)
    assert tl["verify_rounds"] == 2
    assert tl["accepted_total"] == 1
    # stall = (4.5 - 1.0) - prefill - decode - preempted
    assert tl["stall_s"] == pytest.approx(3.5 - 0.75 - 0.8 - 1.0)


def test_null_tracker_is_shared_noop():
    assert NULL_REQUEST_TRACKER.enabled is False
    assert NULL_REQUEST_TRACKER.timelines() == []
    assert NULL_REQUEST_TRACKER.timeline(0) is None
    NULL_REQUEST_TRACKER.on_round(None, 0, 0.0, 1.0)   # never raises


# ---------------------------------------------------------------------------
# bench_compare: the regression gate itself


def _baseline_digest():
    return {
        "untraced_tok_per_s": 10.0, "traced_tok_per_s": 5.0,
        "untraced_fused_compiles": 1,
        "utilization": {"gpu_busy_frac": 0.9},
        "ttft": {"p50": 1.0, "p95": 2.0},
    }


def test_bench_compare_passes_on_identical_digest():
    from benchmarks.bench_compare import compare_digests
    base = _baseline_digest()
    rep = compare_digests(base, json.loads(json.dumps(base)))
    assert rep["ok"] and all(c["ok"] for c in rep["checks"])


def test_bench_compare_fails_on_synthetic_regression():
    from benchmarks.bench_compare import compare_digests
    base = _baseline_digest()
    regressed = json.loads(json.dumps(base))
    regressed["untraced_tok_per_s"] = 1.0          # collapsed throughput
    regressed["ttft"]["p95"] = 60.0                # latency blow-up
    regressed["untraced_fused_compiles"] = 2       # shape leak
    rep = compare_digests(base, regressed)
    assert not rep["ok"]
    failed = {c["name"] for c in rep["checks"] if not c["ok"]}
    assert {"untraced_tok_per_s", "ttft_p95_s",
            "fused_compiles"} <= failed
    # a metric missing from the baseline is skipped, not failed
    del base["ttft"]
    rep2 = compare_digests(base, regressed)
    skipped = {c["name"]: c for c in rep2["checks"]}
    assert skipped["ttft_p95_s"]["ok"]
    assert "skipped" in skipped["ttft_p95_s"]["note"]


def test_bench_compare_tolerances_applied():
    from benchmarks.bench_compare import compare_digests
    base = _baseline_digest()
    mild = json.loads(json.dumps(base))
    mild["untraced_tok_per_s"] = 6.0    # 0.6x: inside the 0.35 floor
    mild["ttft"]["p50"] = 2.5           # 2.5x: inside the 3x ceiling
    assert compare_digests(base, mild)["ok"]
    assert not compare_digests(base, mild,
                               {"tol_throughput": 0.9})["ok"]


def test_committed_baseline_has_gate_metrics():
    """The committed BENCH_serving_obs.json must expose every metric the
    CI gate keys on (else the gate silently skips them)."""
    from benchmarks.bench_compare import CHECKS, _lookup
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving_obs.json")
    with open(path) as f:
        base = json.load(f)
    for name, keys, _, _ in CHECKS:
        v = _lookup(base, keys)
        assert v is not None and v == v, f"baseline missing {name}"
