"""Observability subsystem: Chrome-trace schema, Prometheus round trip,
histogram percentiles, bubble accounting, zero-cost disabled mode, and
the metrics-path regression that a serving run reports fused == 1."""
import tracemalloc

import numpy as np
import pytest

from repro.obs import NULL_OBS, Obs, make_obs
from repro.obs.metrics import (NULL_REGISTRY, Registry, acceptance_buckets)
from repro.obs.schema import (parse_prometheus_text, validate_chrome_trace,
                              validate_metrics_snapshot)
from repro.obs.trace import NULL_TRACER, Tracer, bubble_report
from repro.serving.engine import SchedulerConfig, ServeRequest, ServingEngine

from conftest import tiny_config, tiny_draft_config


def _serve(trace: bool, n_req: int = 5, seed: int = 0):
    se = ServingEngine(tiny_config(("attn",)), tiny_draft_config(),
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              trace=trace))
    se.init_from_seed(0)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_req):
        p = rng.integers(0, 61, int(rng.integers(5, 13))).astype(np.int32)
        r = ServeRequest(i, p, max_new_tokens=int(rng.integers(3, 8)))
        reqs.append(r)
        se.submit(r)
    done = se.run()
    return se, reqs, done


@pytest.fixture(scope="module")
def traced():
    """One trace-enabled serving run shared by the trace assertions."""
    return _serve(trace=True)


# ---------------------------------------------------------------------------
# Chrome trace-event export


def test_chrome_trace_schema(traced):
    se, _, done = traced
    assert len(done) == 5
    trace = se.chrome_trace()
    assert validate_chrome_trace(trace) == []
    evs = trace["traceEvents"]
    assert any(e["ph"] == "X" for e in evs)
    assert any(e["ph"] == "i" for e in evs)


def test_trace_tracks_cover_pipeline_phases(traced):
    se, _, _ = traced
    evs = se.chrome_trace()["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    for track in ("round", "target_verify", "draft_generate", "rollback",
                  "prefill", "admit"):
        assert track in names, f"missing {track} track"


def test_trace_ts_dur_sane(traced):
    se, _, _ = traced
    evs = [e for e in se.chrome_trace()["traceEvents"] if e["ph"] == "X"]
    assert evs
    for e in evs:
        assert e["ts"] >= 0 and e["dur"] >= 0
    # the anti-phase twins: each fused verify span has a draft mirror
    # covering exactly the same interval
    verify = [e for e in evs if e["name"] == "verify(fused)"]
    draft = [e for e in evs if e["name"] == "draft(fused)"]
    assert len(verify) == len(draft) > 0
    for ve, de in zip(verify, draft):
        assert ve["ts"] == pytest.approx(de["ts"], abs=1.0)
        assert ve["dur"] == pytest.approx(de["dur"], abs=1.0)


def test_virtual_clock_stamped(traced):
    se, _, _ = traced
    evs = [e for e in se.chrome_trace()["traceEvents"]
           if e["ph"] == "X" and "args" in e]
    stamped = [e for e in evs if "virtual_s" in e["args"]]
    assert stamped, "spans should carry the scheduler's virtual clock"


# ---------------------------------------------------------------------------
# bubble accounting (the paper's utilization metric)


def test_bubble_report_consistency(traced):
    se, _, _ = traced
    rep = se.metrics()
    util = rep["utilization"]
    assert util["rounds"] == se.stats()["rounds"]
    assert len(util["per_round"]) == util["rounds"]
    for r in util["per_round"]:
        assert 0.0 <= r["busy_frac"] <= 1.0
        assert r["busy_s"] + r["stall_s"] == pytest.approx(r["dur_s"],
                                                           rel=1e-6)
    assert util["busy_s"] + util["stall_s"] == pytest.approx(
        util["wall_s"], rel=1e-6)
    assert 0.0 < util["gpu_busy_frac"] <= 1.0
    assert util["stall_s"] >= 0.0


def test_tracing_does_not_retrace_fused(traced):
    """Spans wrap the jit boundary from outside: enabling tracing must
    not change the fused program's shapes or trigger retraces."""
    se, _, _ = traced
    assert se.stats()["fused_compiles"] == 1


def test_metrics_snapshot_schema_and_contents(traced):
    se, _, _ = traced
    rep = se.metrics()
    snap = rep["metrics"]
    assert validate_metrics_snapshot(snap) == []
    # acceptance histogram: integer buckets, measured rate in [0, 1]
    hist = snap["histograms"]["spec_accepted_tokens"][""]
    n_cand = se.config.n_cand
    assert hist["count"] > 0
    rate = hist["sum"] / (hist["count"] * n_cand)
    assert 0.0 <= rate <= 1.0
    # per-tier transfer accounting (admission KV splice is h2d)
    assert snap["counters"]["transfer_bytes_total"]['{tier="h2d"}'] > 0
    assert ('{tier="h2d"}'
            in snap["counters"]["transfer_seconds_total"])
    # paged-KV block gauges, all drained at end of run
    assert snap["gauges"]["kv_blocks"]['{alloc="h0",state="used"}'] == 0


# ---------------------------------------------------------------------------
# satellite regression: fused == 1 through the metrics path


def test_fused_compiles_once_via_metrics_registry():
    """Full serving run (default metrics-on, trace-off config) must
    report exactly one fused trace through the counter registry."""
    se, _, done = _serve(trace=False, n_req=4, seed=3)
    assert len(done) == 4
    snap = se.metrics()["metrics"]
    ctr = snap["counters"]["pipeline_traces_total"]
    assert ctr['{entry="fused"}'] == 1
    assert ctr['{entry="rollback"}'] == 1
    # trace-off mode records no spans and no utilization report
    assert "utilization" not in se.metrics()
    assert se.chrome_trace()["traceEvents"] == []


# ---------------------------------------------------------------------------
# Prometheus exposition


def test_prometheus_round_trip():
    reg = Registry()
    reg.counter("req_total", "requests").inc(3, tenant="a")
    reg.counter("req_total").inc(1, tenant="b")
    reg.gauge("occupancy", "slots").set(0.625)
    h = reg.histogram("acc", "accepted", buckets=acceptance_buckets(4))
    for v in (0, 1, 1, 4, 2):
        h.observe(v)
    parsed = parse_prometheus_text(reg.prometheus_text())
    assert parsed["req_total"]["type"] == "counter"
    assert parsed["req_total"]["samples"][(("tenant", "a"),)] == 3.0
    assert parsed["req_total"]["samples"][(("tenant", "b"),)] == 1.0
    assert parsed["occupancy"]["samples"][()] == 0.625
    buckets = parsed["acc_bucket"]["samples"]
    assert buckets[(("le", "0"),)] == 1.0          # cumulative
    assert buckets[(("le", "1"),)] == 3.0
    assert buckets[(("le", "4"),)] == 5.0
    assert buckets[(("le", "+Inf"),)] == 5.0
    assert parsed["acc_sum"]["samples"][()] == 8.0
    assert parsed["acc_count"]["samples"][()] == 5.0


def test_prometheus_endpoint_parses(traced):
    se, _, _ = traced
    parsed = parse_prometheus_text(se.prometheus())
    assert "pipeline_traces_total" in parsed
    assert parsed["pipeline_traces_total"]["samples"][
        (("entry", "fused"),)] == 1.0


def test_histogram_percentiles():
    # exact when one bucket holds one distinct value
    reg = Registry()
    h = reg.histogram("x", buckets=acceptance_buckets(4))
    h.observe(2.0)
    assert h.percentile(50) == pytest.approx(2.0)
    # uniform stream: bucket interpolation lands within a bucket width
    h2 = reg.histogram("u", buckets=tuple(np.linspace(0, 1, 21)))
    vals = np.linspace(0.0, 1.0, 201)
    for v in vals:
        h2.observe(float(v))
    width = 0.05
    for p in (10, 50, 90, 99):
        exact = float(np.percentile(vals, p))
        assert abs(h2.percentile(p) - exact) <= width
    assert h2.percentile(0) >= 0.0
    assert h2.percentile(100) == pytest.approx(1.0)


def test_registry_kind_collision_rejected():
    reg = Registry()
    reg.counter("x_total")
    with pytest.raises(TypeError):
        reg.gauge("x_total")


def test_prometheus_label_escaping_round_trip():
    """Label values with quotes, backslashes, newlines and braces must
    survive exposition -> parse (format 0.0.4 escaping)."""
    nasty = 'he"llo\n{x}\\'
    reg = Registry()
    reg.counter("esc_total").inc(7, tenant=nasty, ok="plain")
    reg.histogram("esc_lat", buckets=(1.0,)).observe(0.5, tenant=nasty)
    text = reg.prometheus_text()
    assert '\\"' in text and "\\n" in text and "\\\\" in text
    assert "\n{x}" not in text            # raw newline would split lines
    parsed = parse_prometheus_text(text)
    key = (("ok", "plain"), ("tenant", nasty))
    assert parsed["esc_total"]["samples"][key] == 7.0
    assert parsed["esc_lat_count"]["samples"][(("tenant", nasty),)] == 1.0


def test_histogram_percentile_edge_cases():
    from repro.obs.metrics import DEFAULT_BUCKETS
    reg = Registry()
    # empty series / never-observed labelset -> nan, never a crash
    h = reg.histogram("edge", buckets=acceptance_buckets(4))
    assert np.isnan(h.percentile(50))
    assert np.isnan(h.percentile(50, tenant="ghost"))
    # single observation: every percentile is that observation
    h.observe(3.0)
    for p in (0, 50, 100):
        assert h.percentile(p) == pytest.approx(3.0)
    # all observations in one bucket: clamped to [min, max]
    h2 = reg.histogram("one_bucket", buckets=DEFAULT_BUCKETS)
    for _ in range(50):
        h2.observe(0.042)
    for p in (0, 25, 99, 100):
        assert h2.percentile(p) == pytest.approx(0.042)
    # p=0 -> min, p=100 -> max, both exact
    h3 = reg.histogram("spread", buckets=DEFAULT_BUCKETS)
    for v in (0.002, 0.3, 7.0):
        h3.observe(v)
    assert h3.percentile(0) == pytest.approx(0.002)
    assert h3.percentile(100) == pytest.approx(7.0)


def test_registry_concurrent_snapshot_while_observe():
    """The async front door scrapes snapshot()/prometheus_text() from
    the event loop while the engine thread observes: no exceptions, and
    every histogram snapshot keeps count == +Inf cumulative."""
    import threading

    reg = Registry()
    stop = threading.Event()
    errs: list = []

    def writer():
        i = 0
        try:
            while not stop.is_set():
                # new labelsets force dict growth mid-iteration
                reg.counter("w_total").inc(1, shard=str(i % 37))
                reg.gauge("w_g").set(i, shard=str(i % 11))
                reg.histogram("w_h").observe((i % 100) / 100.0,
                                             shard=str(i % 7))
                i += 1
        except Exception as e:          # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=writer) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        for _ in range(200):
            snap = reg.snapshot()
            assert validate_metrics_snapshot(snap) == []
            parse_prometheus_text(reg.prometheus_text())
            for series in snap["histograms"].get("w_h", {}).values():
                assert series["count"] == series["buckets"]["+Inf"]
            reg.histogram("w_h").percentile(99, shard="3")
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert errs == []


# ---------------------------------------------------------------------------
# disabled mode: zero cost, nothing allocated per round


def _null_round(tr, reg):
    """The per-round obs surface the engine loop touches, null-mode."""
    with tr.span("round", "round") as sp:
        sp.fence(None)
        sp.set("k", 1)
        sp.rename("idle")
    tr.instant("admit", "admitted")
    tr.complete("draft_generate", "d", 0.0, 1.0, cat="device")
    reg.counter("c_total").inc(1.0, tier="h2d")
    reg.gauge("g").set(2.0)
    reg.histogram("h").observe(0.5)


def test_disabled_tracing_shares_one_span():
    s1 = NULL_TRACER.span("round", "round")
    s2 = NULL_TRACER.span("h2d", "stream", cat="device")
    assert s1 is s2, "disabled spans must be one shared object"
    assert NULL_OBS.enabled is False


def test_disabled_tracing_no_retained_allocations():
    """Disabled-mode obs must not accumulate anything per round: after
    thousands of null rounds, traced memory returns to baseline (an
    enabled tracer retains events — the sensitivity check)."""
    rounds = 5000
    _null_round(NULL_TRACER, NULL_REGISTRY)     # warm call sites
    tracemalloc.start()
    base = tracemalloc.get_traced_memory()[0]
    for _ in range(rounds):
        _null_round(NULL_TRACER, NULL_REGISTRY)
    grown = tracemalloc.get_traced_memory()[0] - base
    tracemalloc.stop()
    assert grown < 4096, f"null obs retained {grown} bytes"

    live = Obs(Tracer(fence=False), Registry())
    tracemalloc.start()
    base = tracemalloc.get_traced_memory()[0]
    for _ in range(rounds):
        _null_round(live.tracer, live.metrics)
    grown_live = tracemalloc.get_traced_memory()[0] - base
    tracemalloc.stop()
    assert grown_live > 100 * 1024, "sanity: live tracer retains events"


# ---------------------------------------------------------------------------
# bubble accounting on synthetic spans (unit-level)


def test_bubble_union_does_not_double_count():
    tr = Tracer(fence=False)
    with tr.span("round", "round"):
        with tr.span("target_verify", "v", cat="device") as sp:
            pass
    # mirror the same interval on the draft track (anti-phase twin)
    tr.complete("draft_generate", "d", sp.t0, sp.t1, cat="device")
    rep = bubble_report(tr)
    assert rep["rounds"] == 1
    # overlapped twins count once: busy <= round duration
    assert rep["per_round"][0]["busy_s"] <= rep["per_round"][0]["dur_s"]


def test_bubble_idle_rounds_excluded():
    tr = Tracer(fence=False)
    with tr.span("round", "idle"):
        pass
    with tr.span("round", "round"):
        with tr.span("prefill", "p", cat="device"):
            pass
    rep = bubble_report(tr)
    assert rep["rounds"] == 1
    assert rep["idle_s"] >= 0.0


def test_make_obs_modes():
    assert make_obs(trace=False, metrics=False) is NULL_OBS
    obs = make_obs(trace=True, metrics=False)
    assert obs.tracer.enabled and not obs.metrics.enabled
    assert obs.enabled
