"""Continuous-batching scheduler: EOS retirement, mid-flight admission,
losslessness, shape-stable compilation, metrics, planner occupancy hook."""
import numpy as np
import pytest

from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.configs.base import MISTRAL_7B, MIXTRAL_8X7B
from repro.serving.engine import (SchedulerConfig, ServeRequest,
                                  ServingEngine, latency_percentiles)
from repro.serving.trace import poisson_arrivals, poisson_requests
from repro.sim.hardware import ENV1

from conftest import greedy_reference, tiny_config, tiny_draft_config


@pytest.fixture(scope="module")
def served():
    """One engine run shared by the admission/losslessness assertions:
    7 requests with mixed prompt lengths and max_new_tokens through a
    2-slot-per-half engine (capacity 4 < queue 7), so sequences retire
    at their own lengths and queued requests join freed slots mid-run."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg, n_cand=2, batch_size=2)
    se.init_from_seed(0)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(7):
        p = rng.integers(0, 61, int(rng.integers(5, 13))).astype(np.int32)
        r = ServeRequest(i, p, max_new_tokens=int(rng.integers(3, 10)))
        reqs.append(r)
        se.submit(r)
    done = se.run()
    return se, reqs, done


def test_midflight_admission_completes_all(served):
    se, reqs, done = served
    assert len(done) == len(reqs)
    assert se.pending() == 0
    # queue exceeded capacity, so someone had to wait for a freed slot
    assert any(r.queue_s > 0 for r in reqs)


def test_uneven_max_new_tokens_respected(served):
    _, reqs, _ = served
    lens = {r.rid: len(r.result) for r in reqs}
    assert len(set(r.max_new_tokens for r in reqs)) > 1
    for r in reqs:
        assert lens[r.rid] == r.max_new_tokens  # eos_id=-1: exact length


def test_losslessness_per_sequence(served, jitted):
    """Admission into a mid-flight batch must not perturb any sequence:
    every emitted stream equals a target-only greedy decode of that
    prompt alone."""
    se, reqs, _ = served
    tcfg = se.target_cfg
    for r in reqs:
        ref = greedy_reference(se.engine.tp, tcfg,
                               np.asarray(r.prompt)[None, :],
                               r.max_new_tokens, 64, jitted)
        assert (np.asarray(ref)[0] == r.result).all(), f"rid {r.rid}"


def test_fused_step_compiles_once(served):
    """Slot retirement/admission must never change the fused step's
    shapes — one trace for the whole serving lifetime."""
    se, _, _ = served
    pipe = se.engine.pipeline(se.config.n_cand)
    assert pipe.trace_counts["fused"] == 1
    assert pipe.trace_counts["rollback"] == 1


def test_metrics_recorded(served):
    se, reqs, done = served
    st = se.stats()
    assert st["rounds"] > 0 and st["wall_s"] > 0
    assert 0.0 < st["mean_occupancy"] <= 1.0
    assert se.throughput(done) > 0
    for r in reqs:
        assert r.ttft_s >= r.queue_s >= 0
        assert r.latency_s >= r.ttft_s
        assert r.tok_per_s > 0
    pct = latency_percentiles(done, "latency_s")
    assert pct["p50"] <= pct["p95"] <= pct["p99"]


def test_eos_early_retirement(jitted):
    """A sequence retires the moment it emits EOS — and the truncated
    stream still matches the greedy reference up to (and including) it."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg, n_cand=2, batch_size=2)
    se.init_from_seed(0)
    rng = np.random.default_rng(42)
    prompt = rng.integers(0, 61, 9).astype(np.int32)
    gen = 10
    ref = np.asarray(greedy_reference(se.engine.tp, tcfg, prompt[None, :],
                                      gen, 64, jitted))[0]
    # pick the token the target greedily emits mid-stream as the EOS id
    k = 4
    eos = int(ref[k])
    stop = int(np.where(ref == eos)[0][0])  # first occurrence wins
    se.config.eos_id = eos
    se.submit(ServeRequest(0, prompt, max_new_tokens=gen))
    # a second request with a different (absent) suffix runs to full length
    p2 = rng.integers(0, 61, 7).astype(np.int32)
    r2 = ServeRequest(1, p2, max_new_tokens=6)
    se.submit(r2)
    done = se.run()
    r1 = next(r for r in done if r.rid == 0)
    assert len(r1.result) == stop + 1 < gen
    assert (r1.result == ref[:stop + 1]).all()
    ref2 = np.asarray(greedy_reference(se.engine.tp, tcfg, p2[None, :],
                                       6, 64, jitted))[0]
    exp2 = ref2
    hits = np.where(ref2 == eos)[0]
    if hits.size:
        exp2 = ref2[:int(hits[0]) + 1]
    assert (r2.result == exp2).all()


def test_queue_longer_than_capacity_with_arrivals():
    """Poisson trace with queue length >> batch capacity: everything
    completes, arrivals are honored (no TTFT before arrival), and the
    engine keeps occupancy meaningful."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=2, n_cand=2))
    se.init_from_seed(0)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 61, 8).astype(np.int32) for _ in range(9)]
    reqs = poisson_requests(prompts, 4, rate_rps=50.0, seed=7)
    for r in reqs:
        se.submit(r)
    done = se.run()
    assert len(done) == 9 and se.pending() == 0
    for r in reqs:
        assert len(r.result) == 4
        assert r.admitted_s >= r.arrival_s
        assert r.first_token_s >= r.admitted_s
    st = se.stats()
    assert 0.0 < st["mean_occupancy"] <= 1.0
    assert st["fused_compiles"] == 1


def test_sjf_admission_prefers_short_jobs():
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=1, n_cand=2,
                                              admission="sjf"))
    se.init_from_seed(0)
    rng = np.random.default_rng(5)
    # submitted long-first; SJF should finish the short ones earlier
    lens = [12, 3, 3, 12]
    for i, g in enumerate(lens):
        se.submit(ServeRequest(i, rng.integers(0, 61, 6).astype(np.int32),
                               max_new_tokens=g))
    done = se.run()
    assert len(done) == 4
    short_done = max(r.finished_s for r in done if r.max_new_tokens == 3)
    long_done = max(r.finished_s for r in done if r.max_new_tokens == 12)
    assert short_done < long_done


def test_engine_reusable_across_runs():
    """Halves and compiled programs persist: a second submit/run cycle
    reuses the same fused program."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              max_len=40))
    se.init_from_seed(0)
    rng = np.random.default_rng(9)
    se.submit(ServeRequest(0, rng.integers(0, 61, 8).astype(np.int32), 4))
    d1 = se.run()
    se.submit(ServeRequest(1, rng.integers(0, 61, 8).astype(np.int32), 5))
    d2 = se.run()
    assert len(d1) == 1 and len(d2) == 1
    assert se.stats()["fused_compiles"] == 1


def test_contiguous_mode_regression(jitted):
    """SchedulerConfig(paged=False) keeps the original per-slot
    (B, max_len) splice path working and lossless."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=2, n_cand=2,
                                              paged=False))
    se.init_from_seed(0)
    rng = np.random.default_rng(21)
    reqs = [ServeRequest(i, rng.integers(0, 61, 8).astype(np.int32), 5)
            for i in range(3)]
    for r in reqs:
        se.submit(r)
    done = se.run()
    assert len(done) == 3
    assert se.kv_stats()["paged"] is False
    for r in reqs:
        ref = greedy_reference(se.engine.tp, tcfg,
                               np.asarray(r.prompt)[None, :], 5, 64, jitted)
        assert (np.asarray(ref)[0] == r.result).all()


def test_submit_rejects_oversized_request_gracefully():
    """A request that could never fit the KV budget is refused — not
    crashed on: submit() returns False, stamps the reason, and the
    rejection counter ticks, so live serving just moves on."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=1, n_cand=2,
                                              max_len=32))
    se.init_from_seed(0)
    big = ServeRequest(0, np.zeros(30, np.int32), 8)   # needs 51 > 32
    assert se.submit(big) is False
    assert big.rejected == "never_fits"
    assert se.pending() == 0 and se.rejected_total == 1
    assert se.obs.metrics.counter(
        "serve_requests_rejected_total").value(
            reason="never_fits", tenant="default") == 1
    assert se.stats()["rejected"] == 1
    # a fitting request on the same engine is still served
    ok = ServeRequest(1, np.zeros(6, np.int32), 4)
    assert se.submit(ok) is True
    assert len(se.run()) == 1 and len(ok.result) == 4


def test_submit_rejects_when_bounded_queue_full():
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=1, n_cand=2,
                                              max_queue=2))
    se.init_from_seed(0)
    reqs = [ServeRequest(i, np.zeros(6, np.int32), 3) for i in range(3)]
    assert se.submit(reqs[0]) and se.submit(reqs[1])
    assert se.submit(reqs[2]) is False
    assert reqs[2].rejected == "queue_full"
    assert se.pending() == 2


def test_multi_run_clock_monotonic():
    """Regression for the virtual-clock reset bug: a max_rounds-
    exhausted run() leaves a request queued; the next run() must NOT
    rebase the clock underneath it.  Every stamp stays non-negative and
    completion times are non-decreasing across the two runs."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=1, n_cand=2))
    se.init_from_seed(0)
    rng = np.random.default_rng(11)
    early = ServeRequest(0, rng.integers(0, 61, 6).astype(np.int32), 8)
    # arrives far in the future (beyond any jit-compile wall charge) so
    # the first run() exhausts max_rounds with it still queued on the
    # old clock; the idle fast-forward covers the gap in run 2
    late = ServeRequest(1, rng.integers(0, 61, 6).astype(np.int32), 4,
                        arrival_s=1e4)
    se.submit(early)
    se.submit(late)
    first = se.run(max_rounds=2)
    assert se.pending() >= 1          # `late` still queued
    clock_before = se.now()
    # a fresh submission between runs lands on the same live clock
    fresh = ServeRequest(2, rng.integers(0, 61, 6).astype(np.int32), 4)
    se.submit(fresh)
    done = first + se.run()
    # never rebased under the queue: run-2 admissions continue past the
    # run-1 clock (a reset would stamp `fresh` near zero again)
    assert fresh.admitted_s >= clock_before
    assert len(done) == 3
    for r in (early, late, fresh):
        assert r.admitted_s >= r.arrival_s >= 0.0
        assert r.queue_s >= 0.0 and r.ttft_s >= 0.0
        assert r.latency_s >= 0.0
    fins = [r.finished_s for r in done]   # retirement order
    assert all(a <= b for a, b in zip(fins, fins[1:]))
    # a fully drained engine still starts the next trace at t=0
    assert not se.has_work()
    replay = ServeRequest(3, rng.integers(0, 61, 6).astype(np.int32), 3)
    se.submit(replay)
    se.run()
    assert replay.admitted_s < late.arrival_s


def test_windowed_throughput_attribution():
    """throughput(done) over a subset divides by the wall time of the
    run window(s) that served it — not the engine's lifetime wall.
    Regression for the subset-over-full-wall underreporting bug."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=2, n_cand=2))
    se.init_from_seed(0)
    rng = np.random.default_rng(13)

    def batch(base, n=3, gen=5):
        rs = [ServeRequest(base + i,
                           rng.integers(0, 61, 6).astype(np.int32), gen)
              for i in range(n)]
        for r in rs:
            se.submit(r)
        return rs

    a = batch(0)
    done_a = se.run()
    b = batch(10)
    done_b = se.run()
    assert len(done_a) == len(done_b) == 3
    assert len(se._windows) == 2
    toks_a = sum(len(r.result) for r in a)
    toks_b = sum(len(r.result) for r in b)
    # each subset is attributed exactly its own run's wall window
    assert se.throughput(done_a) == pytest.approx(
        toks_a / se._windows[0])
    assert se.throughput(done_b) == pytest.approx(
        toks_b / se._windows[1])
    # lifetime view still spans everything
    assert se.throughput() == pytest.approx(
        (toks_a + toks_b) / se.stats()["wall_s"])
    # run-2 subset rate is NOT diluted by run 1's wall time
    assert se.throughput(done_b) > toks_b / se.stats()["wall_s"]


# ---------------------------------------------------------------------------
# planner effective-occupancy term


def test_planner_occupancy_scales_throughput():
    pl = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
    pol = Policy(80, 192, 8, 8)
    full = pl.evaluate(pol, Workload(503, 48, 0.75, occupancy=1.0))
    half = pl.evaluate(pol, Workload(503, 48, 0.75, occupancy=0.5))
    assert half.throughput < full.throughput
    # decode rounds still pay full-slot compute, so useful throughput
    # falls at least as fast as occupancy on the decode-bound side
    assert half.throughput < full.throughput * 0.75


def test_planner_search_with_occupancy_feasible():
    pl = ParaSpecPlanner(MIXTRAL_8X7B, MISTRAL_7B, ENV1)
    rep = pl.search(Workload(503, 48, 0.75, occupancy=0.4))
    assert rep.feasible and rep.throughput > 0


def test_planner_kv_bytes_per_seq_term():
    """Measured resident-KV bytes (int8 + block-rounded) shrink the
    host-attention KV traffic term, never the compute terms."""
    from repro.core.planner import stored_kv_bytes_per_seq
    cfg = MIXTRAL_8X7B
    ctx = 503 + 24
    bf16 = stored_kv_bytes_per_seq(cfg, ctx)
    int8 = stored_kv_bytes_per_seq(cfg, ctx, quant=True)
    paged = stored_kv_bytes_per_seq(cfg, ctx, block_size=16)
    assert int8 < bf16                      # 1B + scales beats 2B values
    assert paged >= bf16                    # fragmentation rounds up
    pl = ParaSpecPlanner(cfg, MISTRAL_7B, ENV1)
    pol = Policy(80, 192, 8, 8)
    base = pl.evaluate(pol, Workload(503, 48, 0.75))
    quant = pl.evaluate(pol, Workload(503, 48, 0.75,
                                      kv_bytes_per_seq=int8))
    assert quant.detail["t_attn_host"] <= base.detail["t_attn_host"]
    assert quant.throughput >= base.throughput


def test_online_replan_fires_on_occupancy_drift():
    """With a tight drift threshold and low real occupancy, the engine
    re-runs the ParaSpec search and records a suggested policy."""
    tcfg = tiny_config(("attn",))
    dcfg = tiny_draft_config()
    se = ServingEngine(tcfg, dcfg,
                       config=SchedulerConfig(max_batch=4, n_cand=2,
                                              replan_threshold=0.2,
                                              replan_interval=2))
    se.init_from_seed(0)
    rng = np.random.default_rng(11)
    # one request in an 8-slot engine -> occupancy 1/8, far from planned 1.0
    se.submit(ServeRequest(0, rng.integers(0, 61, 8).astype(np.int32), 12))
    se.run()
    assert se.replan_events, "occupancy drift should trigger a re-search"
    assert se.suggested_policy is not None
    assert se.replan_events[0]["occupancy"] < 0.5


# ---------------------------------------------------------------------------
# trace helpers


def test_poisson_arrivals_monotone():
    arr = poisson_arrivals(5.0, 100, seed=1)
    assert (np.diff(arr) > 0).all()
    assert abs(np.mean(np.diff(arr)) - 0.2) < 0.1
