"""Model / run configuration for the repro framework.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the SpecOffload paper's own models (Mixtral 8x7B/8x22B,
Mistral 7B draft) live here too.  Configs are frozen dataclasses so they can
be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


# Layer kinds usable in ``layer_pattern``.
ATTN = "attn"      # global (full, causal) attention
SWA = "swa"        # sliding-window (local) attention
RGLRU = "rglru"    # RG-LRU recurrent block (Griffin / RecurrentGemma)
RWKV = "rwkv"      # RWKV-6 time-mix block (attention-free)

LAYER_KINDS = (ATTN, SWA, RGLRU, RWKV)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters + framework knobs.

    ``layer_pattern`` is the repeating *layer group*; the model has
    ``n_layers / len(layer_pattern)`` groups and the forward pass is a
    ``lax.scan`` over groups (compile-time friendly for 126-layer models).
    """

    name: str
    arch_type: str                       # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    layer_pattern: tuple = (ATTN,)
    sliding_window: int = 4096
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 2.0
    # dropless dispatch (capacity = n_tokens): exact but memory-heavy; used
    # for decode phases and correctness tests, not for large-batch prefill
    moe_dropless: bool = False
    # which layer_pattern positions use the MoE FFN (None -> all, when moe);
    # e.g. llama4-maverick interleaves dense and MoE layers 1:1
    moe_pattern: tuple = ()
    # positional / misc
    rope_theta: float = 10_000.0
    use_rope: bool = True
    norm: str = "rmsnorm"                # rmsnorm|layernorm
    activation: str = "swiglu"           # swiglu|gelu|geglu
    tie_embeddings: bool = False
    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500              # stub frontend frames
    # recurrent (RG-LRU)
    rnn_width: int = 0                   # 0 -> d_model
    conv_width: int = 4
    # RWKV
    rwkv_head_size: int = 64
    # numerics / compile
    dtype: str = "bfloat16"
    # KV-cache storage dtype for full-attention layers: 'bfloat16' or
    # 'int8' (per-row-per-head absmax quantization; halves the
    # memory-dominant decode working set — EXPERIMENTS.md §Perf).
    # Sliding-window ring caches stay bf16 (they are small by design).
    kv_cache_dtype: str = "bfloat16"
    remat: bool = True
    # offload the per-layer-group residual carry to pinned host memory
    # during training (ZeRO-R-style; the paper's offload tier applied to
    # the training substrate).  Falls back to sqrt-remat when False.
    offload_carries: bool = False
    # capability flags
    supports_long_context: bool = False  # may run the 500k decode shape
    optimizer: str = "adamw"             # adamw|adafactor (giants)
    source: str = ""                     # citation for the config

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        if self.n_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"layer_pattern of length {len(self.layer_pattern)}"
            )
        for k in self.layer_pattern:
            if k not in LAYER_KINDS:
                raise ValueError(f"unknown layer kind {k!r}")
        if self.arch_type == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError(f"{self.name}: moe arch needs n_experts/top_k")
        if self.is_moe and not self.moe_pattern:
            object.__setattr__(self, "moe_pattern",
                               tuple(k in (ATTN, SWA)
                                     for k in self.layer_pattern))
        if self.moe_pattern and len(self.moe_pattern) != len(self.layer_pattern):
            raise ValueError(f"{self.name}: moe_pattern length mismatch")

    # ------------------------------------------------------------------
    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(k in (RGLRU, RWKV) for k in self.layer_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    # -- parameter counting (used by placement / planner / roofline) ----
    def param_count(self) -> int:
        """Total parameters (embedding + layers + head)."""
        d, f = self.d_model, self.d_ff
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        per_layer = 0
        for i, kind in enumerate(self.layer_pattern):
            moe_here = bool(self.is_moe and self.moe_pattern
                            and self.moe_pattern[i])
            per_layer += 2 * d  # two norms
            if kind in (ATTN, SWA):
                hd = self.head_dim
                per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                per_layer += self.n_heads * hd * d
                per_layer += self._ffn_params(moe_here)
            elif kind == RGLRU:
                w = self.rnn_width
                per_layer += 2 * d * w + w * d      # in (x2 branches) + out
                per_layer += self.conv_width * w + w  # temporal conv
                per_layer += 3 * w                   # a_param + gate biases
                per_layer += 2 * w * w // 1          # gates (block-diag approx: dense here)
                per_layer += self._ffn_params(False)
            elif kind == RWKV:
                per_layer += 5 * d * d              # r,k,v,g + out
                per_layer += d * d                  # channel-mix receptance
                per_layer += 2 * d * f              # channel mix up/down
                per_layer += 140 * d                # mus, decay lora, u, ln_x
        n_group_layers = len(self.layer_pattern)
        total = emb + head + self.n_groups * per_layer
        if self.encoder_decoder:
            hd = self.head_dim
            enc_layer = (2 * d
                         + d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                         + self.n_heads * hd * d + self._ffn_params())
            cross = (d + d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                     + self.n_heads * hd * d)
            total += self.n_encoder_layers * enc_layer + self.n_layers * cross
        del n_group_layers
        return total

    def _ffn_params(self, moe: bool | None = None) -> int:
        d, f = self.d_model, self.d_ff
        dense = 3 * d * f if self.activation in ("swiglu", "geglu") else 2 * d * f
        moe = self.is_moe if moe is None else moe
        if moe:
            return self.n_experts * dense + d * self.n_experts  # + router
        return dense

    @property
    def n_moe_layers(self) -> int:
        if not self.is_moe:
            return 0
        return self.n_groups * sum(bool(b) for b in self.moe_pattern)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_ffn = 3 * d * f if self.activation in ("swiglu", "geglu") else 2 * d * f
        inactive = self.n_moe_layers * (self.n_experts - self.top_k) * dense_ffn
        return self.param_count() - inactive

    def param_bytes(self, bytes_per_param: int = 2) -> int:
        return self.param_count() * bytes_per_param

    # ------------------------------------------------------------------
    def reduced(self, d_model: int = 256, n_layers: int = 0, n_experts: int = 4,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 groups, tiny dims."""
        pat = self.layer_pattern
        if n_layers == 0:
            n_layers = len(pat) * min(2, self.n_groups)
        n_heads = max(2, min(4, self.n_heads))
        n_kv = 1 if self.n_kv_heads == 1 else max(1, min(2, self.n_kv_heads))
        while n_heads % n_kv:
            n_kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=d_model * 3,
            vocab_size=vocab,
            n_experts=min(n_experts, self.n_experts) if self.is_moe else 0,
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            rnn_width=d_model,
            sliding_window=min(self.sliding_window, 64),
            n_encoder_layers=min(2, self.n_encoder_layers),
            encoder_len=32 if self.encoder_decoder else self.encoder_len,
            rwkv_head_size=32,
            dtype="float32",
            remat=False,
        )


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (see system brief).
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# The paper's own models (Mixtral target family + Mistral draft).
MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", arch_type="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
    n_experts=8, top_k=2, rope_theta=1e6,
    source="arXiv:2401.04088",
)

MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", arch_type="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=32768,
    n_experts=8, top_k=2, rope_theta=1e6,
    source="mistral.ai/news/mixtral-8x22b",
)

MISTRAL_7B = ModelConfig(
    name="mistral-7b", arch_type="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
    layer_pattern=(SWA,), sliding_window=4096, rope_theta=1e4,
    source="arXiv:2310.06825",
)
