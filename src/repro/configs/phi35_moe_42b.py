"""Phi-3.5-MoE 42B (6.6B active) [moe] — 16 experts, top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    n_experts=16, top_k=2,
    layer_pattern=(ATTN,), rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
