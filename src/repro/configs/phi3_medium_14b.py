"""Phi-3-medium 14B [dense] — RoPE + SwiGLU + GQA (arXiv:2404.14219)."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab_size=100352,
    layer_pattern=(ATTN,), rope_theta=10_000.0,
    source="arXiv:2404.14219",
)
