"""RecurrentGemma-2B [hybrid] — RG-LRU + local attention, 2 recurrent : 1
attention (arXiv:2402.19427).

Sub-quadratic: the RG-LRU state is O(1) and the attention layers use a
2048-token sliding window, so the 500k long-context decode shape runs.
Note MQA (n_kv_heads=1).
"""
from repro.configs.base import RGLRU, SWA, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    n_layers=26 + 1, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256_000,
    layer_pattern=(RGLRU, RGLRU, SWA), sliding_window=2048,
    rnn_width=2560, conv_width=4,
    head_dim=256, tie_embeddings=True,
    supports_long_context=True,
    source="arXiv:2402.19427",
)
# NOTE: the model card has 26 layers; the 1:2 pattern needs a multiple of 3,
# so we run 27 (9 groups) and record the (+1 layer) deviation in DESIGN.md.
