"""StarCoder2-7B [dense] — GQA + RoPE (arXiv:2402.19173).

The released model uses a 4096-token sliding window and GELU MLP; the
assignment line specifies the dense-GQA backbone, which we implement with
global attention + SwiGLU-free (gelu) FFN per the model card.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", arch_type="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    layer_pattern=(ATTN,), rope_theta=1_000_000.0,
    activation="gelu", norm="layernorm",
    source="arXiv:2402.19173",
)
