"""Llama-3.1 405B [dense] — GQA, 128k vocab (arXiv:2407.21783).

The largest assigned architecture: 810 GB of bf16 weights.  Training uses
Adafactor (factored second moment) so optimizer state fits the per-chip
HBM budget at 512-way sharding — AdamW would need ~19 GB/chip on a single
pod (see DESIGN.md §6).
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", arch_type="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    layer_pattern=(ATTN,), rope_theta=500_000.0,
    optimizer="adafactor", offload_carries=True,
    source="arXiv:2407.21783",
)
