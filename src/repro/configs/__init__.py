"""Architecture registry: the 10 assigned architectures (``--arch <id>``)
plus the SpecOffload paper's own models."""
from repro.configs import base
from repro.configs.base import (INPUT_SHAPES, MISTRAL_7B, MIXTRAL_8X7B,
                                MIXTRAL_8X22B, InputShape, ModelConfig)
from repro.configs.chameleon_34b import CONFIG as CHAMELEON_34B
from repro.configs.gemma3_12b import CONFIG as GEMMA3_12B
from repro.configs.llama3_405b import CONFIG as LLAMA3_405B
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK
from repro.configs.phi3_medium_14b import CONFIG as PHI3_MEDIUM
from repro.configs.phi35_moe_42b import CONFIG as PHI35_MOE
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.rwkv6_7b import CONFIG as RWKV6_7B
from repro.configs.starcoder2_7b import CONFIG as STARCODER2_7B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE

# The assigned pool (``--arch`` ids).
ARCHS = {
    "chameleon-34b": CHAMELEON_34B,
    "phi3.5-moe-42b-a6.6b": PHI35_MOE,
    "phi3-medium-14b": PHI3_MEDIUM,
    "recurrentgemma-2b": RECURRENTGEMMA_2B,
    "llama3-405b": LLAMA3_405B,
    "whisper-base": WHISPER_BASE,
    "llama4-maverick-400b-a17b": LLAMA4_MAVERICK,
    "gemma3-12b": GEMMA3_12B,
    "rwkv6-7b": RWKV6_7B,
    "starcoder2-7b": STARCODER2_7B,
}

# The paper's own models (offload engine + benchmarks).
PAPER_MODELS = {
    "mixtral-8x7b": MIXTRAL_8X7B,
    "mixtral-8x22b": MIXTRAL_8X22B,
    "mistral-7b": MISTRAL_7B,
}

ALL_CONFIGS = {**ARCHS, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    try:
        return ALL_CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL_CONFIGS)}")


__all__ = ["ARCHS", "PAPER_MODELS", "ALL_CONFIGS", "get_config",
           "ModelConfig", "InputShape", "INPUT_SHAPES", "base"]
