"""Whisper-base [audio] — encoder-decoder with conv/mel frontend stubbed
(arXiv:2212.04356).

``input_specs`` provides precomputed frame embeddings (B, 1500, 512) in
place of the mel-spectrogram + conv feature extractor; this module is the
transformer that consumes them.  LayerNorm + GELU + learned/sinusoidal
positions (no RoPE).  Decode shapes exercise the decoder with cross
attention to the 1500-frame encoder output; whisper's design maximum is
448 decoder positions, so the 500k long-context shape is skipped
(DESIGN.md §5).
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", arch_type="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    layer_pattern=(ATTN,),
    use_rope=False, norm="layernorm", activation="gelu",
    tie_embeddings=True,
    encoder_decoder=True, n_encoder_layers=6, encoder_len=1500,
    supports_long_context=False,
    source="arXiv:2212.04356",
)
