"""Gemma-3 12B [dense] — 5 local : 1 global attention, 128k context
[hf:google/gemma-3-1b-pt family].

Local layers use a 1024-token sliding window; every 6th layer is global.
``long_500k`` runs: local layers keep only window KV, the 8 global layers
hold the full 512k KV sharded over the mesh (DESIGN.md §5/§6).
"""
from repro.configs.base import ATTN, SWA, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", arch_type="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab_size=262144,
    layer_pattern=(SWA, SWA, SWA, SWA, SWA, ATTN), sliding_window=1024,
    rope_theta=1_000_000.0,
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt",
)
