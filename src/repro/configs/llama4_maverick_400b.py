"""Llama-4 Maverick 400B (17B active) [moe] — 128 experts top-1, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

Like the released Maverick, MoE layers interleave 1:1 with dense layers
(24 MoE + 24 dense of the 48), which lands the total at ~400B with 128
experts of d_ff=8192.  Early fusion: image patches arrive as tokens of the
202k vocabulary (frontend stubbed).  Uses Adafactor for train_4k for the
same HBM-budget reason as llama3-405b.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    n_experts=128, top_k=1,
    layer_pattern=(ATTN, ATTN), moe_pattern=(False, True),
    rope_theta=500_000.0,
    optimizer="adafactor", offload_carries=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
