"""RWKV-6 "Finch" 7B [ssm] — attention-free, data-dependent decay
(arXiv:2404.05892).

No KV cache at all: per-layer state is a (heads, 64, 64) WKV matrix plus
token-shift vectors, so every decode shape including 500k runs in O(1)
state.  The paper's host-attention offload leg is inapplicable (noted in
DESIGN.md §4); weight streaming and speculative decoding still apply —
verification uses the recurrent state-stack rollback.
"""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", arch_type="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=14336, vocab_size=65536,
    layer_pattern=(RWKV,), rwkv_head_size=64,
    head_dim=64,  # informational; attention-free
    supports_long_context=True,
    source="arXiv:2404.05892",
)
