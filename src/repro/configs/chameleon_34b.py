"""Chameleon-34B [vlm] — early-fusion mixed-modal decoder (arXiv:2405.09818).

Images are VQ-tokenized into the same discrete vocabulary as text (early
fusion), so the backbone is a dense decoder with a 65536 vocab; the VQ-VAE
image tokenizer is the stubbed modality frontend (``input_specs`` feeds
token ids directly — image patches arrive as vocabulary entries).
Chameleon uses query-key normalization internally; we keep the standard
pre-norm GQA block (backbone-equivalent compute/memory footprint).
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", arch_type="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    layer_pattern=(ATTN,), rope_theta=10_000.0,
    supports_long_context=False,
    source="arXiv:2405.09818",
)
