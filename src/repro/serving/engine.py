"""Batched request serving on top of the SpecOffload engine.

The paper's workload is offline batch inference: a queue of prompts is
drained in fixed-size batches (the planner's ``bs_decode x 2``), each batch
generated with the dual-batch interleaved pipeline.  This engine adds the
request-level plumbing: queueing, padding to common length (prompts are
bucketed by length), EOS handling, and detokenized-result bookkeeping.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline import SpecOffloadEngine
from repro.data.pipeline import pad_batch
from repro.sim.hardware import ENV1, HardwareSpec


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int = 32
    result: np.ndarray | None = None
    latency_s: float = 0.0


@dataclass
class ServingEngine:
    target_cfg: ModelConfig
    draft_cfg: ModelConfig
    hw: HardwareSpec = ENV1
    n_cand: int = 4
    batch_size: int = 8           # per interleaved half-batch x2 total
    eos_id: int = -1              # -1: never stop early
    engine: SpecOffloadEngine = field(init=False)
    _queue: list = field(default_factory=list)

    def __post_init__(self):
        self.engine = SpecOffloadEngine(self.target_cfg, self.draft_cfg,
                                        self.hw)

    def load(self, target_params, draft_params):
        self.engine.load(target_params, draft_params)

    def init_from_seed(self, seed: int = 0):
        self.engine.init_from_seed(seed)

    def submit(self, req: ServeRequest):
        self._queue.append(req)

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    def run(self) -> list:
        """Drain the queue; returns completed requests."""
        done = []
        while self._queue:
            n = 2 * self.batch_size
            batch = self._queue[:n]
            self._queue = self._queue[n:]
            # pad the wave to a full batch by repeating the last request
            reqs = list(batch)
            while len(reqs) < n:
                reqs.append(ServeRequest(-1, reqs[-1].prompt, 1))
            t0 = time.time()
            prompts = pad_batch([r.prompt for r in reqs])
            gen_len = max(r.max_new_tokens for r in reqs)
            res = self.engine.generate(
                np.asarray(prompts), gen_len=gen_len, n_cand=self.n_cand)
            dt = time.time() - t0
            for i, r in enumerate(batch):
                toks = res.tokens[i, :r.max_new_tokens]
                if self.eos_id >= 0:
                    stop = np.where(toks == self.eos_id)[0]
                    if stop.size:
                        toks = toks[:stop[0] + 1]
                r.result = toks
                r.latency_s = dt
                done.append(r)
        return done

    def throughput(self, done: list) -> float:
        toks = sum(len(r.result) for r in done)
        t = max(r.latency_s for r in done)
        return toks / max(t, 1e-9)
