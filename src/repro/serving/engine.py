"""Continuous-batching request scheduler on top of the SpecOffload engine.

The paper's workload is offline batch inference: fixed padded waves run to
the longest ``max_new_tokens``.  This engine replaces that with a
**continuous-batching scheduler** over the stepwise engine core
(:meth:`SpecOffloadEngine.prefill_batch` / :meth:`decode_round`):

* Each of the two interleaved half-batches is a fixed-shape
  :class:`BatchState` of ``max_batch`` **slots**.  The fused
  verify+draft jit step therefore compiles once and is reused for the
  whole serving lifetime — sequences retire and join without any
  shape-driven recompilation.
* Per-slot sequence state (request, emitted tokens, EOS/length tracking)
  lives host-side.  A sequence **retires** the moment it emits EOS or
  reaches its own ``max_new_tokens``; nothing waits for the longest
  request in a wave.
* Freed slots are refilled **mid-flight** at round boundaries: a queued
  request is prefilled on admission via the zig-zag path (§4.1.1) and
  its target+draft KV is spliced into the freed cache slot.  Admission
  happens only while the half's ``drafts`` are un-staged (right after it
  was verified), so speculative state always covers the slot contents
  and per-sequence outputs stay token-identical to a target-only greedy
  decode (the losslessness invariant, tested in
  ``tests/test_scheduler.py``).
* Requests carry an ``arrival_s`` timestamp; the scheduler admits only
  arrived requests and fast-forwards its virtual clock over idle gaps,
  so Poisson traces replay deterministically.  Per-request metrics
  (queue time, TTFT, decode latency, tokens/s) and engine metrics
  (occupancy, rounds, throughput) are recorded on that clock.

Round structure (one scheduler iteration)::

      admit -> [fused verify(half V) + draft(half W)] -> retire -> swap
                 ^ one jit program, fixed shapes          V's drafts are
                                                          None: slot
                                                          surgery is safe

When a :class:`SchedulerConfig` enables it, the engine re-runs the
ParaSpec policy search online with the *measured* occupancy (the
planner's effective-occupancy term) and records the suggested policy.

Beyond closed-loop trace replay, the scheduler core is **reentrant**:
:meth:`ServingEngine.run_step` executes exactly one iteration and
``run()`` is just a loop over it.  The asyncio front door
(:mod:`repro.serving.server`) drives ``run_step`` directly with
``SchedulerConfig(clock="real")`` (wall clock instead of the virtual
trace clock), streams tokens through ``emit_hook``/``finish_hook`` as
they retire, and layers multi-tenant QoS on admission: priority
classes, weighted per-tenant fair ordering (``qos=True``), and
preemption of long-tail decodes (``preempt=True`` — progress is saved
and the request is re-prefilled over prompt+progress on re-admission,
keeping the greedy stream lossless).
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.core.interleave import BatchState
from repro.core.offload import record_transfer
from repro.core.pipeline import SpecOffloadEngine, required_cache_len
from repro.core.planner import (ParaSpecPlanner, Policy, Workload,
                                kv_bytes_per_token)
from repro.core.spec_decode import (record_acceptance, tree_n_nodes,
                                    tree_supported)
from repro.models.transformer import (admit_sequence_paged, init_cache,
                                      init_paged_cache, release_slot_paged)
from repro.obs import (NULL_REQUEST_TRACKER, FlightRecorder,
                       RequestTracker, SLOMonitor, as_slos, bubble_report,
                       make_obs)
from repro.obs.metrics import LATENCY_BUCKETS
from repro.serving.paged_kv import BlockAllocator, prefix_block_keys
from repro.sim.hardware import ENV1, HardwareSpec


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int = 32
    arrival_s: float = 0.0        # relative to run() start (trace replay)
    result: np.ndarray | None = None
    latency_s: float = 0.0        # end-to-end: arrival -> finished
    # scheduler-stamped metrics (virtual clock, seconds from run() start)
    admitted_s: float = float("nan")
    first_token_s: float = float("nan")
    finished_s: float = float("nan")
    # ---- QoS (multi-tenant serving; defaults keep single-tenant runs
    # byte-identical to the pre-QoS scheduler) ----
    tenant: str = "default"
    priority: int = 1             # lower value = more urgent class
    progress: list = field(default_factory=list)  # tokens emitted before
                                  # a preemption; re-admission prefills
                                  # prompt+progress and resumes exactly
    admitted_prompt: np.ndarray | None = None  # bucket-padded prompt,
                                  # frozen at first admission so a
                                  # post-preemption resume rebuilds the
                                  # identical context
    preemptions: int = 0
    rejected: str | None = None   # submit()-time rejection reason
    # run-window indices for windowed throughput attribution
    admitted_run: int = -1
    finished_run: int = -1

    @property
    def queue_s(self) -> float:
        """Time spent queued before a slot freed up."""
        return self.admitted_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token (arrival -> prefill argmax available)."""
        return self.first_token_s - self.arrival_s

    @property
    def decode_s(self) -> float:
        """First token -> last token."""
        return self.finished_s - self.first_token_s

    @property
    def tok_per_s(self) -> float:
        n = 0 if self.result is None else len(self.result)
        return n / max(self.latency_s, 1e-9)


@dataclass
class SchedulerConfig:
    """Continuous-batching knobs (see module docstring)."""
    max_batch: int = 8            # slots per interleaved half (total 2x)
    n_cand: int = 4               # draft candidates per round (chain mode)
    spec_tree: tuple | None = None  # speculation-tree branching per depth
                                  # (e.g. (3, 2)); None keeps the linear
                                  # chain of n_cand drafts.  Requires all-
                                  # attention target AND draft models.
    eos_id: int = -1              # -1: never stop early
    admission: str = "fifo"       # "fifo" | "sjf" (shortest job first)
    length_bucket: int | None = None   # left-pad admitted prompts up to a
                                  # multiple of this many tokens so prefill
                                  # compiles per bucket, not per length.
                                  # Pads are attended: outputs condition on
                                  # the padded prompt (exactness per padded
                                  # prompt, not per raw prompt) — leave
                                  # None when bitwise losslessness vs. the
                                  # raw prompt matters.
    pad_id: int = 0
    max_len: int | None = None    # per-slot KV capacity; derived from the
                                  # queue at first run() when None
    prefill_chunk: int = 8        # zig-zag microbatch size on admission
    replan_threshold: float | None = None  # occupancy drift that triggers
                                  # an online ParaSpec re-search (None: off)
    replan_accept_drift: float | None = None  # measured-acceptance drift
                                  # (per-depth fraction, EMA over live
                                  # slots) that triggers a chain-vs-tree
                                  # budget re-search (None: off)
    replan_interval: int = 32     # rounds between drift checks
    # ---- clock + admission bounds (async front door) ----
    clock: str = "virtual"        # "virtual": trace replay, advances by
                                  # measured step wall time and fast-
                                  # forwards idle gaps; "real": wall
                                  # seconds since engine construction
                                  # (the async server's mode)
    max_queue: int | None = None  # bounded admission queue: submit()
                                  # past this depth is a graceful
                                  # rejection, never an exception
    # ---- multi-tenant QoS (layered on `admission`) ----
    qos: bool = False             # order arrivals by (priority class,
                                  # weighted per-tenant virtual time)
                                  # before the FIFO/SJF key
    tenant_weights: dict = field(default_factory=dict)  # tenant ->
                                  # fair-share weight (default 1.0)
    preempt: bool = False         # evict long-tail decodes when a
                                  # strictly higher-priority request is
                                  # starved (progress saved + requeued)
    preempt_min_remaining: int = 4  # never evict a decode with fewer
                                  # tokens left than this (it will free
                                  # the slot soon anyway)
    # ---- paged KV substrate (target full-attention layers only) ----
    paged: bool = True            # block-table pool instead of per-slot
                                  # (B, max_len) target KV; False keeps the
                                  # contiguous splice path
    block_size: int = 16          # tokens per KV block
    num_blocks: int | None = None # per-half pool size (incl. the reserved
                                  # scratch block 0); None -> enough for
                                  # every slot at full max_len (no pressure)
    kv_quant_cold: bool = False   # int8-quantize the pool (quantize-on-
                                  # write; contiguous-int8 numerics)
    prefix_cache: bool = True     # hash-chain dedup of full prompt blocks
    # ---- observability (repro.obs) ----
    metrics: bool = True          # labeled counter/gauge/histogram registry
                                  # behind ServingEngine.metrics(); cheap,
                                  # on by default
    trace: bool = False           # span tracer -> Chrome trace JSON +
                                  # bubble/utilization accounting.  Off by
                                  # default: fencing serializes dispatch to
                                  # get honest per-phase device timing
    trace_fence: bool = True      # block_until_ready at device-span exit
    trace_annotations: bool = False  # jax.profiler.TraceAnnotation per span
    # ---- request-scoped observability + SLOs (repro.obs) ----
    request_timeline: bool = False  # per-request phase timelines (queue/
                                  # prefill/decode/preempted/stall) +
                                  # req:{rid} Chrome tracks.  Host-side
                                  # only: never crosses a jit boundary,
                                  # so outputs stay token-identical
    slos: tuple = ()              # declarative objectives (repro.obs.SLO
                                  # instances or plain dicts) evaluated
                                  # at first token and retirement
    flight_recorder: bool = True  # always-on bounded ring of round
                                  # records; dumps a postmortem bundle on
                                  # SLO violations / anomaly signals
                                  # (inactive when all obs is off)
    flight_capacity: int = 256    # ring capacity, rounds
    postmortem_dir: str | None = None  # bundle output directory (None:
                                  # triggers are counted, nothing is
                                  # ever written to disk)
    postmortem_cooldown_s: float = 30.0  # min seconds between bundles
    postmortem_max_bundles: int = 4      # lifetime bundle cap


@dataclass
class _Slot:
    """Host-side state of one cache slot in one interleaved half."""
    req: ServeRequest | None = None
    emitted: list = field(default_factory=list)
    done: bool = True             # True: free (or holding a retired seq)
    blocks: list = field(default_factory=list)  # granted KV blocks (paged)
    accept_ema: float = 0.7       # EMA of this sequence's per-round
                                  # acceptance fraction (accepted depth /
                                  # depth budget); feeds replanning


def latency_percentiles(done: list, attr: str = "latency_s",
                        ps=(50, 95, 99)) -> dict:
    """p50/p95/p99 (seconds) of a per-request metric over completed reqs."""
    vals = np.asarray([getattr(r, attr) for r in done], np.float64)
    if vals.size == 0:
        return {f"p{p}": float("nan") for p in ps}
    return {f"p{p}": float(np.percentile(vals, p)) for p in ps}


@dataclass
class ServingEngine:
    """Continuous-batching front door; see the module docstring.

    ``n_cand``/``batch_size``/``eos_id`` are legacy shortcuts — they seed
    a default :class:`SchedulerConfig` when ``config`` is not given.
    """
    target_cfg: ModelConfig
    draft_cfg: ModelConfig
    hw: HardwareSpec = ENV1
    n_cand: int = 4
    batch_size: int = 8           # per interleaved half-batch x2 total
    eos_id: int = -1              # -1: never stop early
    config: SchedulerConfig | None = None
    engine: SpecOffloadEngine = field(init=False)
    _queue: list = field(default_factory=list)

    def __post_init__(self):
        if self.config is None:
            self.config = SchedulerConfig(max_batch=self.batch_size,
                                          n_cand=self.n_cand,
                                          eos_id=self.eos_id)
        if self.config.spec_tree is not None:
            self.config.spec_tree = tuple(self.config.spec_tree)
            for name, cfg in (("target", self.target_cfg),
                              ("draft", self.draft_cfg)):
                if not tree_supported(cfg):
                    raise ValueError(
                        f"spec_tree requires an all-attention decoder-only "
                        f"{name} model (layer_pattern="
                        f"{cfg.layer_pattern!r})")
            tree_n_nodes(self.config.spec_tree)   # validates the node cap
        self.obs = make_obs(trace=self.config.trace,
                            metrics=self.config.metrics,
                            fence=self.config.trace_fence,
                            annotations=self.config.trace_annotations,
                            virtual_clock=lambda: self._now)
        # request-scoped observability: per-request timelines, SLO
        # monitor, always-on flight recorder (see repro.obs.request_trace
        # / repro.obs.slo).  All host-side; NULL tracker when off.
        cfg = self.config
        self.requests = (RequestTracker(tracer=self.obs.tracer,
                                        clock=lambda: self._now)
                         if cfg.request_timeline else NULL_REQUEST_TRACKER)
        self._slos = as_slos(cfg.slos)
        self.recorder = None
        if cfg.flight_recorder and (self.obs.enabled or self._slos
                                    or cfg.postmortem_dir
                                    or cfg.request_timeline):
            self.recorder = FlightRecorder(
                capacity=cfg.flight_capacity,
                out_dir=cfg.postmortem_dir,
                cooldown_s=cfg.postmortem_cooldown_s,
                max_bundles=cfg.postmortem_max_bundles)
        self.slo_monitor = (SLOMonitor(self._slos,
                                       metrics=self.obs.metrics,
                                       tracer=self.obs.tracer,
                                       on_violation=self._on_slo_violation)
                            if self._slos else None)
        self.engine = SpecOffloadEngine(self.target_cfg, self.draft_cfg,
                                        self.hw, obs=self.obs)
        self._splice = jax.jit(_splice_slot)
        self._admit_paged = jax.jit(admit_sequence_paged,
                                    static_argnums=(0,))
        self._release_paged = jax.jit(release_slot_paged)
        self._halves = None           # two BatchState of max_batch slots
        self._slots = None            # parallel host-side _Slot maps
        self._allocs = None           # per-half BlockAllocator (paged mode)
        self._num_blocks = self.config.num_blocks
        self._blocks_granted_seqs = 0  # admissions (for avg-blocks metric)
        self._v = 0                   # index of the next verify half
        self._max_len = self.config.max_len
        self._now = 0.0               # virtual clock (s since run() start)
        self._wall_s = 0.0            # accumulated real wall time in run()
        self._rounds = 0
        self._tokens_out = 0
        self._occ_sum = 0.0
        self._occ_window = []
        self._planned_occ = 1.0
        self._accept_window = []
        self._accept_last = None      # latest live-slot acceptance mean
        self._planned_accept = 0.7    # planner's accept_prob default
        self._len_sum, self._gen_sum, self._req_seen = 0, 0, 0
        self.replan_events = []
        self.suggested_policy: Policy | None = None
        self.suggested_tree: tuple | None = None
        if self.config.clock not in ("virtual", "real"):
            raise ValueError(f"SchedulerConfig.clock must be 'virtual' or "
                             f"'real', got {self.config.clock!r}")
        self._real_clock = self.config.clock == "real"
        self._epoch = time.monotonic()   # real-clock zero point
        self._windows = []            # wall seconds of each sealed run()
        self._open_window_s = 0.0     # wall accumulated since last seal
        self._tenant_vtime = {}       # tenant -> weighted service time
        self._tenants_seen = set()
        self.rejected_total = 0
        self.preempted_total = 0
        self.idle_step = False        # last run_step() only ticked clock
        # per-emission hooks for the async front door (called with
        # (request, token) / (request,) as tokens retire)
        self.emit_hook = None
        self.finish_hook = None

    # ------------------------------------------------------------------
    def load(self, target_params, draft_params):
        self.engine.load(target_params, draft_params)

    def init_from_seed(self, seed: int = 0):
        self.engine.init_from_seed(seed)

    def submit(self, req: ServeRequest) -> bool:
        """Queue a request.  Never raises: a request that could not ever
        fit (KV capacity / block pool) or that finds the bounded
        admission queue full is *rejected* — ``req.rejected`` records
        the reason, ``serve_requests_rejected_total`` counts it, and
        False is returned so trace replays and the async front door's
        backpressure path simply move on to the next request."""
        reason = None
        if (self._max_len is not None
                and self._required_len(req) > self._max_len):
            reason = "never_fits"
        elif (self.config.paged and self.config.num_blocks is not None
                and self._required_blocks(req)
                > self.config.num_blocks - 1):
            reason = "never_fits"
        elif (self.config.max_queue is not None
                and len(self._queue) >= self.config.max_queue):
            reason = "queue_full"
        if reason is not None:
            req.rejected = reason
            self.rejected_total += 1
            if self.obs.enabled:
                self.obs.metrics.counter(
                    "serve_requests_rejected_total",
                    "requests rejected at submit (never fits / bounded "
                    "queue full)").inc(1, reason=reason, tenant=req.tenant)
            self.requests.on_reject(req, reason)
            if self.recorder is not None:
                self.recorder.record_instant(
                    "rejected", {"rid": req.rid, "reason": reason,
                                 "tenant": req.tenant})
            return False
        self._tenants_seen.add(req.tenant)
        self.requests.on_submit(req)
        self._queue.append(req)
        return True

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    # scheduler clock

    def now(self) -> float:
        """Scheduler clock (s): the virtual trace clock, or wall seconds
        since engine construction in real-clock mode."""
        if self._real_clock:
            self._refresh_now()
        return self._now

    def _refresh_now(self):
        self._now = time.monotonic() - self._epoch

    def _tick(self, dt: float):
        """Advance the clock past a step that took ``dt`` wall seconds
        (virtual mode adds it; the real clock advances on its own)."""
        if self._real_clock:
            self._refresh_now()
        else:
            self._now += dt

    def has_live(self) -> bool:
        """True while any slot holds an unfinished sequence."""
        return (self._slots is not None
                and any(not s.done for half in self._slots for s in half))

    def has_work(self) -> bool:
        return self.has_live() or bool(self._queue)

    def _cand_equiv(self) -> int:
        """Per-round uncommitted-token budget for cache sizing: tree mode
        stages the whole flattened buffer (n_nodes rows, root included),
        chain mode n_cand drafts + the root."""
        if self.config.spec_tree is not None:
            return tree_n_nodes(self.config.spec_tree) - 1
        return self.config.n_cand

    def _depth_cap(self) -> int:
        """Max accepted draft tokens per verify round (the deepest
        root-to-leaf path in tree mode, n_cand in chain mode)."""
        if self.config.spec_tree is not None:
            return len(self.config.spec_tree)
        return self.config.n_cand

    def _required_len(self, req: ServeRequest) -> int:
        # the bucket applies to the prompt alone (frozen at first
        # admission); a preempted request re-prefills prompt+progress
        # with only its remaining tokens left to generate, so the total
        # never exceeds the first admission's reservation
        l = len(req.prompt)
        if self.config.length_bucket:
            b = self.config.length_bucket
            l = -(-l // b) * b
        l += len(req.progress)
        return required_cache_len(l, req.max_new_tokens - len(req.progress),
                                  self._cand_equiv())

    def _required_blocks(self, req: ServeRequest) -> int:
        return -(-self._required_len(req) // self.config.block_size)

    # ------------------------------------------------------------------
    # slot bootstrap / admission

    def _ensure_halves(self):
        if self._halves is not None:
            return
        cfg = self.config
        if self._max_len is None:
            if not self._queue:
                raise ValueError("run() with an empty queue and no "
                                 "SchedulerConfig.max_len to size caches")
            self._max_len = max(self._required_len(r) for r in self._queue)
        if cfg.paged:
            # Round capacity to a block multiple so the contiguous
            # (B=1, max_len) prefill caches and the paged serving caches
            # agree on every non-ATTN leaf shape.
            bs = cfg.block_size
            self._max_len = -(-self._max_len // bs) * bs
            mbs = self._max_len // bs
            if self._num_blocks is None:
                # pressure-free default: every slot can reach max_len
                self._num_blocks = 1 + cfg.max_batch * mbs
            nb = self._num_blocks
            self._halves = []
            for _ in range(2):
                tc = init_paged_cache(
                    self.target_cfg, cfg.max_batch, nb, bs, mbs,
                    kv_quant=True if cfg.kv_quant_cold else None)
                dc = init_cache(self.draft_cfg, cfg.max_batch,
                                self._max_len)
                self._halves.append(BatchState(
                    target_cache=tc, draft_cache=dc,
                    t_next=jnp.zeros((cfg.max_batch,), jnp.int32),
                    drafts=None, draft_pendings=None, emitted=[]))
            self._allocs = [BlockAllocator(nb, obs=self.obs, name=f"h{h}")
                            for h in range(2)]
        else:
            # Park a 1-token dummy sequence in every slot: shapes are fixed
            # forever, real requests are spliced in by _admit().
            dummy = np.zeros((cfg.max_batch, 1), np.int32)
            self._halves = [
                self.engine.prefill_batch(dummy, self._max_len,
                                          cfg.max_batch)
                for _ in range(2)]
        self._slots = [[_Slot() for _ in range(cfg.max_batch)]
                       for _ in range(2)]

    def _admission_order(self, arrived: list) -> list:
        if self.config.admission == "sjf":
            arrived = sorted(arrived,
                             key=lambda r: (r.max_new_tokens,
                                            len(r.prompt)))
        if self.config.qos:
            # priority class first, then weighted fair sharing: tenants
            # are ordered by accumulated virtual service time (charged
            # at admission as (prompt+remaining)/weight), so a tenant
            # that has consumed less of its share goes first.  The sort
            # is stable, so the FIFO/SJF key still breaks ties.
            arrived = sorted(
                arrived,
                key=lambda r: (r.priority,
                               self._tenant_vtime.get(r.tenant, 0.0)))
        return arrived

    def _charge_tenant(self, req: ServeRequest, prompt_len: int):
        w = float(self.config.tenant_weights.get(req.tenant, 1.0))
        cost = (prompt_len + req.max_new_tokens - len(req.progress))
        self._tenant_vtime[req.tenant] = (
            self._tenant_vtime.get(req.tenant, 0.0) + cost / max(w, 1e-9))

    def _try_grant(self, h: int, prompt: np.ndarray,
                   req: ServeRequest) -> tuple | None:
        """Reserve the request's full block budget from half ``h``'s
        allocator, reusing prefix-cached full-prompt blocks.  Returns
        ``(block_ids, n_shared)``, or None when the pool is currently
        short — the request then simply stays queued until retirements
        free blocks (never a crash; tested in test_paged_kv.py)."""
        cfg = self.config
        alloc = self._allocs[h]
        need = required_cache_len(len(prompt),
                                  req.max_new_tokens - len(req.progress),
                                  self._cand_equiv())
        n_need = -(-need // cfg.block_size)
        keys = (prefix_block_keys(prompt, cfg.block_size)
                if cfg.prefix_cache else [])
        shared = []
        for key in keys:
            bid = alloc.lookup(key)
            if bid is None:
                break
            shared.append(bid)
        if not alloc.can_alloc(n_need - len(shared)):
            for bid in shared:           # roll back the prefix refs
                alloc.decref(bid)
            return None
        block_ids = shared + alloc.alloc(n_need - len(shared))
        for j in range(len(shared), len(keys)):
            alloc.register(block_ids[j], keys[j])
        return block_ids, len(shared)

    def _admit_tokens(self, req: ServeRequest) -> np.ndarray:
        """Prefill token stream for a request: its prompt (bucket-padded
        once, then frozen, so a post-preemption resume re-prefills the
        identical context) extended by any progress saved at
        preemption."""
        if req.admitted_prompt is None:
            toks = np.asarray(req.prompt, np.int32)
            if self.config.length_bucket:
                b = self.config.length_bucket
                tgt = -(-len(toks) // b) * b
                toks = np.concatenate(
                    [np.full(tgt - len(toks), self.config.pad_id,
                             np.int32), toks])
            req.admitted_prompt = toks
        toks = req.admitted_prompt
        if req.progress:
            toks = np.concatenate(
                [toks, np.asarray(req.progress, np.int32)])
        return toks

    def _admit(self, h: int) -> list:
        """Admit arrived requests into free slots of half ``h``.  Only
        legal while the half's drafts are un-staged (drafts is None).
        One request is picked per free slot so the QoS fairness keys
        (updated by each admission's virtual-time charge) stay fresh."""
        half, slots = self._halves[h], self._slots[h]
        assert half.drafts is None, "admission while drafts staged"
        cfg = self.config
        finished = []
        free = [i for i, s in enumerate(slots) if s.done]
        while free and self._queue:
            arrived = [r for r in self._queue if r.arrival_s <= self._now]
            picked = None
            for req in self._admission_order(arrived):
                prompt = self._admit_tokens(req)
                grant = None
                if cfg.paged:
                    grant = self._try_grant(h, prompt, req)
                    if grant is None:    # block pressure: stays queued
                        continue
                picked = (req, prompt, grant)
                break
            if picked is None:
                break
            req, prompt, grant = picked
            slot_idx = free.pop(0)
            self._queue.remove(req)
            req.admitted_s = self._now
            if req.admitted_run < 0:
                req.admitted_run = len(self._windows)
            if cfg.qos:
                self._charge_tenant(req, len(prompt))
            t_wall = time.time()
            pt0 = time.perf_counter()
            with self.obs.tracer.span("admit", "admit") as asp:
                st = self.engine.prefill_batch(prompt[None, :],
                                               self._max_len,
                                               cfg.prefill_chunk)
                if cfg.paged:
                    block_ids, n_shared = grant
                    row = np.zeros(self._max_len // cfg.block_size,
                                   np.int32)
                    row[:len(block_ids)] = block_ids
                    half.target_cache = self._admit_paged(
                        self.target_cfg, half.target_cache,
                        st.target_cache, slot_idx, jnp.asarray(row),
                        len(prompt), n_shared)
                    self._blocks_granted_seqs += 1
                else:
                    half.target_cache = self._splice(
                        half.target_cache, st.target_cache, slot_idx)
                half.draft_cache = self._splice(half.draft_cache,
                                                st.draft_cache, slot_idx)
                asp.fence((half.target_cache, half.draft_cache))
                asp.set("rid", req.rid)
                asp.set("half", h)
                asp.set("slot", slot_idx)
            t0 = int(np.asarray(st.t_next)[0])
            half.t_next = half.t_next.at[slot_idx].set(t0)
            pt1 = time.perf_counter()
            dt = time.time() - t_wall
            self._tick(dt)
            # resumed iff first token already produced (re-admission
            # after a preemption); closes the park interval as queue or
            # preempted time on the request's timeline
            self.requests.on_admit(req, pt0, pt1, half=h, slot=slot_idx,
                                   resumed=not np.isnan(req.first_token_s))
            if self.obs.enabled:
                # splicing the prefilled KV into the serving cache is the
                # engine's host->device KV hand-off (paper Table 3 P row)
                kv_bytes = len(prompt) * (
                    kv_bytes_per_token(self.target_cfg)
                    + kv_bytes_per_token(self.draft_cfg))
                record_transfer(self.obs, "h2d", kv_bytes, dt,
                                what="kv_splice")
                self.obs.metrics.histogram(
                    "admit_seconds",
                    "wall seconds per admission (prefill + splice)"
                ).observe(dt)
                self.obs.tracer.instant(
                    "admit", "admitted",
                    {"rid": req.rid, "half": h, "slot": slot_idx,
                     "prompt_len": len(prompt)})
            if np.isnan(req.first_token_s):   # not set on re-admission
                req.first_token_s = self._now
                if self.obs.enabled:
                    self.obs.metrics.histogram(
                        "serve_ttft_seconds",
                        "arrival -> first token, labeled per tenant",
                        buckets=LATENCY_BUCKETS).observe(
                            req.ttft_s, tenant=req.tenant)
                if self.slo_monitor is not None:
                    self.slo_monitor.observe_ttft(req)
            slot = slots[slot_idx]
            slot.req = req
            slot.emitted = list(req.progress) + [t0]
            slot.done = False
            slot.blocks = list(grant[0]) if grant else []
            if self.emit_hook is not None:
                self.emit_hook(req, t0)
            self._len_sum += len(prompt)
            self._gen_sum += req.max_new_tokens
            self._req_seen += 1
            # a 1-token request (or instant EOS) finishes at admission
            if ((cfg.eos_id >= 0 and t0 == cfg.eos_id)
                    or len(slot.emitted) >= req.max_new_tokens):
                self._finish(h, slot_idx)
                finished.append(req)
        return finished

    def _finish(self, h: int, idx: int):
        slot = self._slots[h][idx]
        req = slot.req
        req.result = np.asarray(slot.emitted, np.int32)
        req.finished_s = self._now
        req.finished_run = len(self._windows)
        req.latency_s = self._now - req.arrival_s
        self._tokens_out += len(req.result)
        if self.obs.enabled:
            self.obs.metrics.counter(
                "serve_requests_total",
                "requests completed by the scheduler").inc(1)
            self.obs.tracer.instant(
                "admit", "retired",
                {"rid": req.rid, "half": h, "slot": idx,
                 "tokens": len(req.result)})
        self.requests.on_finish(req)
        if self.slo_monitor is not None:
            self.slo_monitor.observe_finish(
                req, self.requests.timeline(req.rid))
        self._release_slot(h, idx)
        if self.finish_hook is not None:
            self.finish_hook(req)

    def _release_slot(self, h: int, idx: int):
        """Clear a slot and return its KV blocks to the pool (shared by
        retirement and preemption).  The paged table row + pos are
        nulled *before* the blocks can be re-granted: the vacated slot
        keeps riding the fused step, and its dead decode writes must
        land in the scratch block, not in blocks now owned by another
        sequence."""
        slot = self._slots[h][idx]
        slot.req, slot.emitted, slot.done = None, [], True
        if self.config.paged and slot.blocks:
            half = self._halves[h]
            half.target_cache = self._release_paged(half.target_cache, idx)
            alloc = self._allocs[h]
            for bid in slot.blocks:
                alloc.decref(bid)
            slot.blocks = []

    def preempt(self, h: int, idx: int) -> ServeRequest:
        """Evict the live sequence in slot ``idx`` of half ``h``: its
        emitted tokens are saved as ``req.progress``, its KV blocks
        return to the pool, and the request rejoins the queue (original
        arrival stamp, so its place in FIFO order is kept).  On
        re-admission the engine prefills prompt+progress, so the resumed
        greedy stream continues exactly where it stopped (losslessness
        is tested in tests/test_async_server.py).  Only legal while the
        half's drafts are un-staged — same window as admission."""
        half = self._halves[h]
        assert half.drafts is None, "preemption while drafts staged"
        slot = self._slots[h][idx]
        req = slot.req
        req.progress = list(slot.emitted)
        req.preemptions += 1
        self.preempted_total += 1
        self.requests.on_preempt(req)
        if self.recorder is not None:
            self.recorder.record_instant(
                "preempted", {"rid": req.rid, "tenant": req.tenant,
                              "progress": len(req.progress)})
        self._release_slot(h, idx)
        self._queue.append(req)
        if self.obs.enabled:
            self.obs.metrics.counter(
                "serve_requests_preempted_total",
                "live decodes evicted for higher-priority arrivals "
                "(progress saved, requeued)").inc(1, tenant=req.tenant)
            self.obs.tracer.instant(
                "admit", "preempted",
                {"rid": req.rid, "half": h, "slot": idx,
                 "progress": len(req.progress)})
        return req

    def _maybe_preempt(self, h: int):
        """Priority preemption: when a strictly higher-priority request
        is waiting and half ``h`` has no free slot, evict the lowest-
        priority live decode with the most remaining tokens (the long
        tail), provided it still has >= preempt_min_remaining to go."""
        slots = self._slots[h]
        if any(s.done for s in slots):
            return                    # a free slot: plain admission wins
        arrived = [r for r in self._queue if r.arrival_s <= self._now]
        if not arrived:
            return
        best = min(r.priority for r in arrived)
        victims = [(s.req.priority,
                    s.req.max_new_tokens - len(s.emitted), i)
                   for i, s in enumerate(slots)
                   if not s.done and s.req.priority > best
                   and (s.req.max_new_tokens - len(s.emitted))
                   >= self.config.preempt_min_remaining]
        if victims:
            _, _, idx = max(victims)
            self.preempt(h, idx)

    def _process_emissions(self, h: int, out) -> list:
        """EOS-aware retirement: append this round's verified tokens to
        each live slot, stopping per sequence at EOS or its own length."""
        cfg = self.config
        finished = []
        for idx, slot in enumerate(self._slots[h]):
            if slot.done:
                continue
            req = slot.req
            for t in out.tokens[idx, :int(out.n_emitted[idx])]:
                tok = int(t)
                slot.emitted.append(tok)
                if self.emit_hook is not None:
                    self.emit_hook(req, tok)
                if ((cfg.eos_id >= 0 and tok == cfg.eos_id)
                        or len(slot.emitted) >= req.max_new_tokens):
                    self._finish(h, idx)
                    finished.append(req)
                    break
        return finished

    # ------------------------------------------------------------------
    # occupancy + online replanning (planner effective-occupancy hook)

    def _record_occupancy(self):
        n_active = sum(1 for half in self._slots for s in half if not s.done)
        occ = n_active / (2 * self.config.max_batch)
        self._occ_sum += occ
        self._occ_window.append(occ)

    def _record_acceptance_ema(self, v: int, out):
        """Fold this round's per-slot acceptance fraction into each live
        sequence's EMA and log the live-slot mean for drift checks."""
        cap = self._depth_cap()
        fracs = []
        for idx, slot in enumerate(self._slots[v]):
            if slot.done:
                continue
            frac = float(out.n_accept[idx]) / max(cap, 1)
            slot.accept_ema = 0.8 * slot.accept_ema + 0.2 * frac
            fracs.append(slot.accept_ema)
        if fracs:
            self._accept_last = float(np.mean(fracs))
            self._accept_window.append(self._accept_last)

    def _maybe_replan(self):
        cfg = self.config
        if ((cfg.replan_threshold is None
                and cfg.replan_accept_drift is None)
                or self._rounds % cfg.replan_interval):
            return
        occ, occ_drifted = self._planned_occ, False
        if cfg.replan_threshold is not None and self._occ_window:
            occ = float(np.mean(self._occ_window))
            self._occ_window = []
            occ_drifted = abs(occ - self._planned_occ) > cfg.replan_threshold
        acc, acc_drifted = self._planned_accept, False
        if cfg.replan_accept_drift is not None and self._accept_window:
            acc = float(np.mean(self._accept_window))
            self._accept_window = []
            acc_drifted = (abs(acc - self._planned_accept)
                           > cfg.replan_accept_drift)
        if not (occ_drifted or acc_drifted):
            return
        wl = Workload(prompt_len=max(1, self._len_sum
                                     // max(1, self._req_seen)),
                      gen_len=max(1, self._gen_sum
                                  // max(1, self._req_seen)),
                      accept_prob=min(max(acc, 0.01), 0.99),
                      occupancy=max(occ, 1e-3),
                      kv_bytes_per_seq=self._kv_bytes_per_seq())
        planner = ParaSpecPlanner(self.target_cfg, self.draft_cfg,
                                  self.hw, obs=self.obs)
        # acceptance-aware replans search the joint chain-vs-tree budget
        # space; pure-occupancy replans keep the paper's chain search
        if cfg.spec_tree is not None or cfg.replan_accept_drift is not None:
            rep = planner.search_spec(wl)
        else:
            rep = planner.search(wl)
        self.suggested_policy = rep.policy
        self.suggested_tree = rep.policy.tree
        self._planned_occ, self._planned_accept = occ, acc
        self.replan_events.append({"round": self._rounds, "occupancy": occ,
                                   "accept_rate": acc,
                                   "policy": rep.policy,
                                   "tree": rep.policy.tree,
                                   "throughput": rep.throughput})

    # ------------------------------------------------------------------
    # wall-time windows (throughput attribution)

    def _close_window(self):
        """Seal the open per-run wall window.  run() seals at exit; a
        direct run_step() driver (the async server) seals at drain."""
        if self._open_window_s > 0.0:
            self._windows.append(self._open_window_s)
            self._open_window_s = 0.0

    def _window_wall(self, i: int) -> float:
        return (self._windows[i] if i < len(self._windows)
                else self._open_window_s)

    # ------------------------------------------------------------------
    def run_step(self) -> list:
        """One scheduler iteration: preempt/admit on whichever half has
        un-staged drafts, one fused verify+draft round, retire.

        Reentrant — ``run()`` is just a loop over this, and the async
        front door (:mod:`repro.serving.server`) drives it directly,
        interleaving event-loop work between rounds.  Returns the
        requests retired by this step (``emit_hook``/``finish_hook``
        fire inside).  ``self.idle_step`` is left True when nothing was
        in flight: in virtual-clock mode the clock fast-forwarded to the
        next arrival; in real-clock mode the caller should sleep/await
        until arrivals are due.
        """
        cfg = self.config
        self.idle_step = False
        if self._halves is None and not self._queue:
            self.idle_step = True
            return []                 # nothing submitted yet: no-op
        self._ensure_halves()
        if self._real_clock:
            self._refresh_now()
        t_step0 = time.time()
        completed = []
        v = self._v
        # One "round" span per scheduler iteration (admit -> fused
        # verify+draft -> retire); renamed "idle" when the engine is
        # empty and only fast-forwards the clock, so bubble accounting
        # never counts waiting-for-arrivals as stall.
        with self.obs.tracer.span("round", "round") as rs:
            # slot surgery is legal on any half without staged drafts
            for h in (v, 1 - v):
                if self._halves[h].drafts is None:
                    if cfg.preempt:
                        self._maybe_preempt(h)
                    completed += self._admit(h)
            if not self.has_live():
                rs.rename("idle")
                self.idle_step = True
                if self._queue and not self._real_clock:
                    # fast-forward the virtual clock to the next arrival
                    self._now = max(self._now,
                                    min(r.arrival_s for r in self._queue))
                dt = time.time() - t_step0
                self._wall_s += dt
                self._open_window_s += dt
                return completed
            live_v = ([not s.done for s in self._slots[v]]
                      if self.obs.metrics.enabled else None)
            t_wall = time.time()
            out = self.engine.decode_round(self._halves[v],
                                           self._halves[1 - v],
                                           cfg.n_cand, record=False,
                                           tree=cfg.spec_tree)
            self._tick(time.time() - t_wall)
            self._rounds += 1
            self._record_occupancy()
            self._record_acceptance_ema(v, out)
            if self.obs.metrics.enabled:
                self._round_metrics(out, live_v)
            if self.requests.enabled:
                # attribute the fused round to every live request BEFORE
                # retirement pops slots: the verified half may have
                # emitted tokens, the anti-phase half got fresh drafts —
                # both are pipeline work done on the request's behalf
                rd = self._rounds - 1
                for idx, slot in enumerate(self._slots[v]):
                    if not slot.done:
                        self.requests.on_round(
                            slot.req, rd, out.t0, out.t1,
                            accepted=int(out.n_accept[idx]),
                            emitted=int(out.n_emitted[idx]), role="verify")
                for slot in self._slots[1 - v]:
                    if not slot.done:
                        self.requests.on_round(slot.req, rd, out.t0,
                                               out.t1, role="draft")
            completed += self._process_emissions(v, out)
            self._maybe_replan()
            self._v = 1 - v
        dt = time.time() - t_step0
        self._wall_s += dt
        self._open_window_s += dt
        if self.recorder is not None:
            # black box: one small record per round + anomaly detectors
            # (works without the span tracer — busy fraction is the
            # fused interval over the round's wall time)
            busy_frac = max(0.0, out.t1 - out.t0) / max(dt, 1e-9)
            self.recorder.record_round(
                {"round": self._rounds - 1, "t0": out.t0, "t1": out.t1,
                 "dur_s": dt, "busy_frac": busy_frac,
                 "queue_depth": len(self._queue),
                 "accept_mean": self._accept_last,
                 "tokens_out": self._tokens_out})
            hit = self.recorder.check(accept_mean=self._accept_last,
                                      busy_frac=busy_frac,
                                      queue_depth=len(self._queue))
            if hit is not None:
                self._postmortem(*hit)
        return completed

    def run(self, max_rounds: int = 100_000) -> list:
        """Serve until the queue and all in-flight sequences drain.

        Returns the requests completed by this call (retirement order).
        The two half-batches and their compiled programs persist across
        calls — submit more requests and call run() again for free.
        """
        if self._halves is None and not self._queue:
            return []                 # nothing submitted yet: no-op
        self._ensure_halves()
        completed = []
        for _ in range(max_rounds):
            completed += self.run_step()
            if not self.has_work():
                break
            if self.idle_step and self._real_clock and self._queue:
                # real clock can't fast-forward: sleep toward the next
                # arrival instead of spinning
                gap = min(r.arrival_s for r in self._queue) - self.now()
                if gap > 0:
                    time.sleep(min(gap, 0.05))
        self._close_window()
        # Rebase the virtual clock only once the engine is *fully*
        # drained: a max_rounds-exhausted run leaves sequences in flight
        # or requests queued, and both carry stamps on the old clock —
        # resetting under them corrupts queue_s/ttft_s/latency_s, so the
        # clock stays monotonic until every reference to it has drained.
        # Resetting at exit (not entry) also lets a fresh trace
        # submitted after a full drain replay from t=0
        # (tests/test_scheduler.py::test_multi_run_clock_monotonic).
        if not self._real_clock and not self.has_work():
            self._now = 0.0
        return completed

    # ------------------------------------------------------------------
    # observability (repro.obs): per-round samples + snapshot export

    def _round_metrics(self, out, live_v: list):
        """Cheap per-round registry updates (metrics mode only)."""
        reg = self.obs.metrics
        reg.gauge("serve_queue_depth",
                  "requests waiting for a free slot").set(len(self._queue))
        if self._tenants_seen:
            g = reg.gauge("serve_tenant_queue_depth",
                          "queued requests, labeled per tenant")
            depth: dict = {}
            for r in self._queue:
                depth[r.tenant] = depth.get(r.tenant, 0) + 1
            for t in self._tenants_seen:
                g.set(depth.get(t, 0), tenant=t)
        reg.gauge("serve_occupancy",
                  "fraction of batch slots holding live sequences").set(
                      self._occ_window[-1] if self._occ_window
                      else self._occ_sum / max(1, self._rounds))
        record_acceptance(reg, out.n_accept, self._depth_cap(),
                          live_mask=live_v, n_draft=self._cand_equiv(),
                          mode="tree" if self.config.spec_tree is not None
                          else "chain")

    def _sync_metrics(self):
        """Bring scrape-time gauges/counters up to date: pipeline trace
        counts, allocator block states, lifetime totals."""
        reg = self.obs.metrics
        pipe = self.engine._pipe
        if pipe is not None:
            pipe.export_trace_counts(reg)
        if self._allocs is not None:
            for a in self._allocs:
                a.export_gauges(reg)
        reg.gauge("serve_rounds_total", "decode rounds executed").set(
            self._rounds)
        reg.gauge("serve_tokens_out_total",
                  "tokens emitted to completed requests").set(
                      self._tokens_out)
        reg.gauge("serve_replans_total",
                  "online ParaSpec replans triggered").set(
                      len(self.replan_events))

    def metrics(self) -> dict:
        """Structured observability snapshot.

        ``{"metrics": <registry snapshot>}`` plus, when tracing is on,
        ``"utilization"`` — the bubble-accounting report derived from
        the recorded spans: per-round GPU busy fraction, total pipeline
        stall (the paper's offload bubble), and idle time.  Use
        ``prometheus()`` for the text exposition of the same registry.
        """
        self._sync_metrics()
        rep = {"metrics": self.obs.metrics.snapshot()}
        if self.obs.tracer.enabled:
            rep["utilization"] = bubble_report(self.obs.tracer)
        return rep

    def prometheus(self) -> str:
        """Prometheus text exposition of the metrics registry."""
        self._sync_metrics()
        return self.obs.metrics.prometheus_text()

    def chrome_trace(self) -> dict:
        """The recorded spans as Chrome trace-event JSON (Perfetto)."""
        return self.obs.tracer.to_chrome_trace()

    # ------------------------------------------------------------------
    # request timelines, SLOs, flight recorder

    def request_timelines(self) -> list:
        """Final JSON timeline digests of every retired request
        (``SchedulerConfig(request_timeline=True)``; [] otherwise)."""
        return self.requests.timelines()

    def request_timeline(self, rid: int) -> dict | None:
        """One request's timeline digest (provisional while live)."""
        return self.requests.timeline(rid)

    def slo_report(self) -> dict | None:
        """Per-(slo, tenant) compliance + violation log, or None when no
        SLOs are configured."""
        return None if self.slo_monitor is None else self.slo_monitor.report()

    def _on_slo_violation(self, slo, event: dict):
        """SLOMonitor callback: log the violation into the black box and
        dump a postmortem bundle (cooldown/cap limited)."""
        if self.recorder is not None:
            self.recorder.record_instant("slo_violation", dict(event))
            self._postmortem(f"slo_{slo.name}", dict(event))

    def _postmortem(self, reason: str, args: dict | None = None):
        """Dump a flight-recorder bundle; sections are callables so a
        cooldown-suppressed trigger costs nothing."""
        if self.recorder is None:
            return None
        path = self.recorder.trigger(
            reason, args,
            metrics=self.metrics,
            engine=self._engine_digest,
            config=self._config_digest)
        if path is not None and self.obs.enabled:
            self.obs.metrics.counter(
                "postmortem_bundles_total",
                "flight-recorder postmortem bundles dumped").inc(
                    1, reason=reason)
            self.obs.tracer.instant("slo", "postmortem",
                                    {"reason": reason, "path": path})
        return path

    def _engine_digest(self) -> dict:
        """Small JSON engine-state summary for postmortem bundles."""
        live = (sum(1 for half in self._slots for s in half if not s.done)
                if self._slots is not None else 0)
        return {"rounds": self._rounds, "tokens_out": self._tokens_out,
                "queue_depth": len(self._queue), "live": live,
                "wall_s": self._wall_s, "now_s": self._now,
                "rejected": self.rejected_total,
                "preempted": self.preempted_total,
                "mean_occupancy": self._occ_sum / max(1, self._rounds),
                "accept_mean": self._accept_last,
                "spec_mode": ("tree" if self.config.spec_tree is not None
                              else "chain")}

    def _config_digest(self) -> dict:
        """Scheduler + planner config as plain JSON."""
        d = asdict(self.config)
        d["slos"] = [s.to_dict() for s in self._slos]
        return d

    # ------------------------------------------------------------------
    def throughput(self, done: list | None = None) -> float:
        """Tokens/s over the engine's accumulated real wall time (not the
        max per-request latency, which overstates multi-wave runs).

        With ``done=None`` this is the engine-lifetime figure (same as
        ``stats()['tok_per_s']``).  Passing a subset of completed
        requests divides that subset's tokens by the wall time of only
        the run windows those requests actually spanned (first admission
        through finishing run), so per-policy A/B subsets served by one
        engine compare on their own wall clock."""
        if done is None:
            return self._tokens_out / max(self._wall_s, 1e-9)
        toks = sum(len(r.result) for r in done if r.result is not None)
        wins: set = set()
        for r in done:
            if r.finished_run >= 0:
                wins.update(range(max(r.admitted_run, 0),
                                  r.finished_run + 1))
        wall = sum(self._window_wall(w) for w in wins)
        return toks / max(wall, 1e-9)

    def _attn_cache_bytes(self, cache: dict) -> int:
        """Bytes of the full-attention KV leaves of a target cache."""
        total = 0
        for i, kind in enumerate(self.target_cfg.layer_pattern):
            if kind == ATTN:
                total += sum(int(leaf.nbytes)
                             for leaf in jax.tree.leaves(cache["layers"][i]))
        return total

    def kv_stats(self) -> dict:
        """KV-memory accounting for the target full-attention layers.

        ``peak_kv_bytes`` is the serving-lifetime high-water mark of KV a
        scheduler must actually keep resident: granted blocks for the
        paged substrate, the whole (B, max_len) cache for the contiguous
        one (every slot is always materialized there).
        """
        if self._halves is None:
            return {}
        cfg = self.config
        if cfg.paged:
            pool_bytes = self._attn_cache_bytes(self._halves[0].target_cache)
            per_block = pool_bytes / self._num_blocks
            peak = sum(a.peak_used for a in self._allocs)
            return {"paged": True, "block_size": cfg.block_size,
                    "num_blocks_per_half": self._num_blocks,
                    "bytes_per_block": per_block,
                    "pool_bytes_total": 2.0 * pool_bytes,
                    "peak_blocks_in_use": peak,
                    "peak_kv_bytes": peak * per_block,
                    "prefix_hits": sum(a.prefix_hits
                                       for a in self._allocs),
                    "prefix_evictions": sum(a.evictions
                                            for a in self._allocs),
                    "allocators": [a.stats() for a in self._allocs]}
        full = float(sum(self._attn_cache_bytes(hf.target_cache)
                         for hf in self._halves))
        return {"paged": False, "pool_bytes_total": full,
                "peak_kv_bytes": full}

    def _kv_bytes_per_seq(self) -> float | None:
        """Average resident target-KV bytes per admitted sequence
        (block granularity; None before any paged admission)."""
        if (not self.config.paged or self._allocs is None
                or not self._blocks_granted_seqs):
            return None
        ks = self.kv_stats()
        granted = sum(a.granted_total for a in self._allocs)
        return ks["bytes_per_block"] * granted / self._blocks_granted_seqs

    def stats(self) -> dict:
        """Engine-level serving metrics."""
        pipe = self.engine._pipe
        return {
            "rounds": self._rounds,
            "tokens_out": self._tokens_out,
            "wall_s": self._wall_s,
            "mean_occupancy": self._occ_sum / max(1, self._rounds),
            "tok_per_s": self._tokens_out / max(self._wall_s, 1e-9),
            "fused_compiles": 0 if pipe is None
            else pipe.trace_counts["fused"],
            "rejected": self.rejected_total,
            "preempted": self.preempted_total,
            "replans": len(self.replan_events),
            "slo_violations": (len(self.slo_monitor.violations)
                               if self.slo_monitor is not None else 0),
            "postmortems": (len(self.recorder.bundles)
                            if self.recorder is not None else 0),
            "spec_mode": ("tree" if self.config.spec_tree is not None
                          else "chain"),
            "spec_tree": self.config.spec_tree,
            "kv": self.kv_stats(),
        }


def _splice_slot(big: dict, small: dict, slot) -> dict:
    """Write sequence 0 of a (B=1) prefill cache into batch slot ``slot``
    of a big cache.  Layer leaves are stacked (n_groups, B, ...); ``pos``
    is (B,).  ``slot`` is a traced scalar, so one compile covers every
    slot index (per cache tree structure)."""
    layers = jax.tree.map(
        lambda b, s: jax.lax.dynamic_update_index_in_dim(
            b, s[:, 0].astype(b.dtype), slot, 1),
        big["layers"], small["layers"])
    pos = jax.lax.dynamic_update_index_in_dim(
        big["pos"], small["pos"][0].astype(big["pos"].dtype), slot, 0)
    return {"layers": layers, "pos": pos}
