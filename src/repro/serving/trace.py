"""Arrival traces for serving benchmarks.

The continuous-batching scheduler replays requests on a virtual clock
(:class:`repro.serving.engine.ServeRequest.arrival_s`), so a trace is just
a deterministic list of (arrival time, prompt, max_new_tokens) tuples —
no threads or sleeps involved.
"""
from __future__ import annotations

import numpy as np

from repro.serving.engine import ServeRequest


def poisson_arrivals(rate_rps: float, n: int, seed: int = 0) -> np.ndarray:
    """Arrival times (s) of a Poisson process: i.i.d. Exp(rate) gaps."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9), n))


def poisson_requests(prompts: list, max_new: list | int,
                     rate_rps: float, seed: int = 0) -> list:
    """Wrap prompts into :class:`ServeRequest`s with Poisson arrivals.

    ``max_new`` may be a scalar or a per-request list (heterogeneous
    generation lengths exercise EOS-aware early retirement).
    """
    arr = poisson_arrivals(rate_rps, len(prompts), seed)
    if np.isscalar(max_new):
        max_new = [int(max_new)] * len(prompts)
    return [ServeRequest(i, np.asarray(p, np.int32), int(g),
                         arrival_s=float(t))
            for i, (p, g, t) in enumerate(zip(prompts, max_new, arr))]
