"""Arrival traces for serving benchmarks.

The continuous-batching scheduler replays requests on a virtual clock
(:class:`repro.serving.engine.ServeRequest.arrival_s`), so a closed-loop
trace is just a deterministic list of (arrival time, prompt,
max_new_tokens) tuples — no threads or sleeps involved.

For the asyncio front door (:mod:`repro.serving.server`) the same trace
becomes an **open-loop load generator**: :func:`replay_open_loop`
submits each request when its arrival time comes due on the real clock
and consumes every stream concurrently, token by token.
"""
from __future__ import annotations

import asyncio

import numpy as np

from repro.serving.engine import ServeRequest


def poisson_arrivals(rate_rps: float, n: int, seed: int = 0) -> np.ndarray:
    """Arrival times (s) of a Poisson process: i.i.d. Exp(rate) gaps."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9), n))


def poisson_requests(prompts: list, max_new: list | int,
                     rate_rps: float, seed: int = 0) -> list:
    """Wrap prompts into :class:`ServeRequest`s with Poisson arrivals.

    ``max_new`` may be a scalar or a per-request list (heterogeneous
    generation lengths exercise EOS-aware early retirement).
    """
    arr = poisson_arrivals(rate_rps, len(prompts), seed)
    if np.isscalar(max_new):
        max_new = [int(max_new)] * len(prompts)
    return [ServeRequest(i, np.asarray(p, np.int32), int(g),
                         arrival_s=float(t))
            for i, (p, g, t) in enumerate(zip(prompts, max_new, arr))]


def tenant_poisson_requests(prompts: list, max_new: list | int,
                            rate_rps: float, tenants: dict,
                            seed: int = 0) -> list:
    """Multi-tenant Poisson trace: one merged arrival process whose
    requests are assigned to tenants i.i.d. by traffic share.

    ``tenants`` maps tenant name -> ``{"share": float, "priority": int}``
    (both optional; share defaults to equal, priority to 1).  The same
    ``seed`` always yields the same (arrival, tenant, priority) labeling,
    so closed-loop and open-loop legs can serve the identical trace.
    """
    reqs = poisson_requests(prompts, max_new, rate_rps, seed)
    names = sorted(tenants)
    shares = np.asarray([float(tenants[t].get("share", 1.0))
                         for t in names], np.float64)
    shares /= shares.sum()
    rng = np.random.default_rng(seed + 1)
    picks = rng.choice(len(names), size=len(reqs), p=shares)
    for r, k in zip(reqs, picks):
        r.tenant = names[int(k)]
        r.priority = int(tenants[r.tenant].get("priority", 1))
    return reqs


async def replay_open_loop(server, reqs: list, speed: float = 1.0
                           ) -> tuple[dict, list]:
    """Open-loop replay of a pre-stamped trace against an
    :class:`repro.serving.server.AsyncServingServer`.

    Each request is submitted when its ``arrival_s / speed`` comes due
    on the server's real clock (open loop: submission never waits for
    earlier requests to finish — only admission backpressure can slow
    it), and a consumer task drains its stream concurrently.  Returns
    ``(tokens, handles)``: ``tokens`` maps rid -> streamed token list
    (None for rejected submissions), ``handles`` is the live
    :class:`ServeRequest` list with scheduler-stamped metrics.
    """
    from repro.serving.server import RequestRejected

    tokens: dict = {}
    handles: list = []
    consumers = []

    async def _consume(handle):
        tokens[handle.rid] = await server.collect(handle)

    t0 = server.engine.now()
    for r in sorted(reqs, key=lambda r: r.arrival_s):
        delay = r.arrival_s / speed - (server.engine.now() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        try:
            h = await server.submit(r.prompt, r.max_new_tokens,
                                    tenant=r.tenant, priority=r.priority,
                                    rid=r.rid)
        except RequestRejected:
            tokens[r.rid] = None
            continue
        handles.append(h)
        consumers.append(asyncio.create_task(_consume(h)))
    await asyncio.gather(*consumers)
    return tokens, handles
