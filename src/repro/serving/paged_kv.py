"""Host-side block bookkeeping for the paged KV cache substrate.

The device side (``repro.models.transformer.init_paged_cache`` and the
paged flash-decode kernel) only sees a ``(num_blocks, block_size, ...)``
pool and per-slot ``(B, max_blocks)`` int32 block tables.  This module
owns the *policy*: which physical block backs which logical block of
which sequence.

* :class:`BlockAllocator` — refcounted free-list allocator over one
  half-batch's pool.  Block 0 is reserved as the scratch block (dead
  slots' writes land there; it is never granted).  Blocks registered
  under a prefix key are not freed when their refcount drops to zero —
  they move to a *cached* LRU tier, where they stay resurrectable by
  :meth:`lookup` until allocation pressure evicts them.  The cached tier
  counts as available capacity, so admission can never deadlock on
  blocks held only by the prefix cache.
* :func:`prefix_block_keys` — hash-chain keys over the *full* prompt
  blocks (``len(prompt) // block_size``).  Chaining makes a block's key
  depend on everything before it, so two prompts share exactly their
  common block-aligned prefix.

Sharing is copy-free by construction: shared blocks hold only prompt
positions ``< len(prompt)``, and decode writes only positions
``>= len(prompt)`` (speculative rewrites included), so a shared block is
never written after registration.  The refcounts exist to keep a block
alive while any sequence's table points at it — the copy-on-write case
never triggers, and the allocator asserts that invariant instead of
implementing the copy.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np


def prefix_block_keys(tokens, block_size: int) -> list:
    """Chained digests for each *full* ``block_size`` chunk of a prompt.

    Only full blocks are keyed: a partial final block is private to its
    sequence (decode continues writing into it), so it must never be
    shared.
    """
    arr = np.ascontiguousarray(np.asarray(tokens, np.int32))
    keys, h = [], b""
    for i in range(len(arr) // block_size):
        chunk = arr[i * block_size:(i + 1) * block_size].tobytes()
        h = hashlib.sha256(h + chunk).digest()
        keys.append(h)
    return keys


class BlockAllocator:
    """Refcounted allocator over ``num_blocks`` physical KV blocks.

    Block ids are ints in ``[1, num_blocks)``; block 0 is the reserved
    scratch block.  Capacity accounting: ``used`` blocks hold live
    (refcounted) data, ``cached`` blocks hold resurrectable prefix data
    (ref 0), the rest are free.  ``can_alloc`` counts free + cached,
    since cached blocks are evicted on demand.
    """

    def __init__(self, num_blocks: int, obs=None, name: str = "kv"):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self.obs = obs
        self.name = name                  # label for metrics/trace events
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> low ids
        self._ref: dict[int, int] = {}
        self._cached: OrderedDict[bytes, int] = OrderedDict()  # LRU: old->new
        self._by_key: dict[bytes, int] = {}
        self._key_of: dict[int, bytes] = {}
        self.peak_used = 0
        self.granted_total = 0        # blocks ever granted (incl. reuse)
        self.prefix_hits = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    @property
    def used(self) -> int:
        """Blocks referenced by at least one live sequence."""
        return self.num_blocks - 1 - len(self._free) - len(self._cached)

    @property
    def cached(self) -> int:
        return len(self._cached)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free) + len(self._cached)

    def _note_usage(self):
        self.peak_used = max(self.peak_used, self.used)

    # ------------------------------------------------------------------
    def alloc(self, n: int) -> list[int]:
        """Take ``n`` fresh blocks (ref 1 each), evicting LRU cached
        prefix blocks if the free list runs short."""
        if not self.can_alloc(n):
            raise RuntimeError(f"allocator exhausted: want {n}, have "
                               f"{len(self._free)} free + "
                               f"{len(self._cached)} cached")
        out = []
        for _ in range(n):
            if not self._free:
                key, bid = self._cached.popitem(last=False)   # evict LRU
                del self._by_key[key]
                del self._key_of[bid]
                self.evictions += 1
                if self.obs.enabled:
                    self.obs.metrics.counter(
                        "kv_prefix_evictions_total",
                        "cached prefix blocks evicted under allocation "
                        "pressure").inc(1, alloc=self.name)
                    self.obs.tracer.instant(
                        "kv", "evict", {"alloc": self.name, "block": bid})
                self._free.append(bid)
            bid = self._free.pop()
            self._ref[bid] = 1
            out.append(bid)
        self.granted_total += n
        self._note_usage()
        return out

    def incref(self, bid: int):
        self._ref[bid] += 1

    def decref(self, bid: int):
        """Drop one reference; at zero the block returns to the free list,
        or parks in the cached tier if it carries a prefix key."""
        self._ref[bid] -= 1
        if self._ref[bid] > 0:
            return
        del self._ref[bid]
        key = self._key_of.get(bid)
        if key is not None:
            self._cached[key] = bid       # newest end of the LRU
        else:
            self._free.append(bid)

    # ------------------------------------------------------------------
    # prefix cache
    def lookup(self, key: bytes) -> int | None:
        """Resurrect-or-share the block holding ``key``'s prompt chunk.
        Returns the block id with an acquired reference, or None."""
        bid = self._by_key.get(key)
        if bid is None:
            return None
        if bid in self._ref:              # live: shared with another seq
            self._ref[bid] += 1
        else:                             # parked in the cached tier
            del self._cached[key]
            self._ref[bid] = 1
        self.prefix_hits += 1
        self.granted_total += 1
        if self.obs.enabled:
            self.obs.metrics.counter(
                "kv_prefix_hits_total",
                "admissions served from prefix-cached blocks").inc(
                    1, alloc=self.name)
        self._note_usage()
        return bid

    def export_gauges(self, registry):
        """Publish the allocator's occupancy picture as labeled gauges
        (free / used / cached block counts + peak and grant counters)."""
        g = registry.gauge("kv_blocks",
                           "paged-KV pool blocks by state per allocator")
        g.set(len(self._free), alloc=self.name, state="free")
        g.set(self.used, alloc=self.name, state="used")
        g.set(self.cached, alloc=self.name, state="cached")
        registry.gauge("kv_blocks_peak_used",
                       "high-water mark of live blocks").set(
                           self.peak_used, alloc=self.name)
        registry.gauge("kv_blocks_granted_total",
                       "blocks ever granted (incl. prefix reuse)").set(
                           self.granted_total, alloc=self.name)

    def register(self, bid: int, key: bytes):
        """Publish a freshly written full-prompt block under its chain
        key.  First writer wins; the block must be live (shared blocks
        are immutable, so re-registering an existing key is a no-op)."""
        assert bid in self._ref, "registering a block with no references"
        if key in self._by_key or bid in self._key_of:
            return
        self._by_key[key] = bid
        self._key_of[bid] = key

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {"num_blocks": self.num_blocks, "used": self.used,
                "cached": self.cached, "free": len(self._free),
                "peak_used": self.peak_used,
                "granted_total": self.granted_total,
                "prefix_hits": self.prefix_hits,
                "evictions": self.evictions}
