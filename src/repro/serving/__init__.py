from repro.serving.engine import (SchedulerConfig, ServeRequest,
                                  ServingEngine, latency_percentiles)
from repro.serving.server import AsyncServingServer, RequestRejected
from repro.serving.trace import (poisson_requests, replay_open_loop,
                                 tenant_poisson_requests)

__all__ = ["SchedulerConfig", "ServeRequest", "ServingEngine",
           "latency_percentiles", "AsyncServingServer", "RequestRejected",
           "poisson_requests", "tenant_poisson_requests",
           "replay_open_loop"]
