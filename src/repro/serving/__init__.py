from repro.serving.engine import ServeRequest, ServingEngine
