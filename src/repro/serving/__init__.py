from repro.serving.engine import (SchedulerConfig, ServeRequest,
                                  ServingEngine, latency_percentiles)

__all__ = ["SchedulerConfig", "ServeRequest", "ServingEngine",
           "latency_percentiles"]
