"""Always-on asyncio front door over the continuous-batching scheduler.

The closed-loop :meth:`repro.serving.engine.ServingEngine.run` replays a
pre-built trace on a virtual clock; this module turns the same stepwise
core into a **live ingest path** — the prerequisite for any sustained-
load claim.  One background task drives ``engine.run_step()`` (in a
worker thread, so the event loop keeps accepting work mid-round) and
fans verified tokens out to per-request streams the moment
``_process_emissions`` retires them:

    eng = ServingEngine(tcfg, dcfg,
                        config=SchedulerConfig(max_batch=4, clock="real",
                                               qos=True, preempt=True))
    eng.init_from_seed(0)
    async with AsyncServingServer(eng, max_queue=32) as srv:
        req = await srv.submit(prompt, max_new_tokens=64,
                               tenant="acme", priority=0)
        async for tok in srv.stream(req):
            ...                        # token-by-token, as verified
    # __aexit__ == drain(): stop admitting, serve out, stop the loop

Semantics:

* **Backpressure** — ``submit()`` awaits while the bounded admission
  queue (``max_queue``) is full; space frees as the engine admits.  A
  ``submit_timeout_s`` turns starvation into :class:`RequestRejected`
  (counted under ``serve_requests_rejected_total``), and a request that
  could *never* fit the engine's KV capacity is rejected immediately —
  the engine-level graceful-rejection path, reused.
* **QoS** — tenancy/priority ride on the engine's admission layer
  (``SchedulerConfig.qos`` / ``tenant_weights`` / ``preempt``): priority
  classes preempt long-tail decodes (progress saved, stream resumes
  losslessly) and weighted fair ordering keeps one tenant from starving
  the rest.  Per-tenant TTFT histograms and queue-depth gauges land in
  the engine's metrics registry.
* **Draining** — :meth:`drain` stops admission (new submits are
  rejected), serves every queued/in-flight request to completion,
  flushes all streams, and stops the background task.

Thread discipline: the engine is only ever touched from one logical
context at a time.  ``submit()`` never calls into the engine directly —
requests park on an ingress deque the serve loop transfers at round
boundaries, and emissions buffered by the engine hooks (fired inside the
worker thread) are flushed to ``asyncio.Queue`` streams from the event
loop after each step returns.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from collections import deque

import numpy as np

from repro.serving.engine import ServeRequest, ServingEngine


class RequestRejected(RuntimeError):
    """A submission was refused: never fits, backpressure timeout, or
    the server is draining.  ``reason`` carries which."""

    def __init__(self, reason: str, rid: int | None = None):
        super().__init__(f"request {rid if rid is not None else '?'} "
                         f"rejected: {reason}")
        self.reason = reason
        self.rid = rid


class AsyncServingServer:
    """``submit()`` / ``stream()`` asyncio facade over a
    :class:`ServingEngine` built with ``SchedulerConfig(clock="real")``.

    ``max_queue`` bounds the admission queue (backpressure);
    ``submit_timeout_s`` bounds how long a submit may wait for room
    (None: forever); ``idle_sleep_s`` is the event-loop nap between
    steps while queued arrivals are not yet due.
    """

    def __init__(self, engine: ServingEngine, max_queue: int = 64,
                 submit_timeout_s: float | None = None,
                 idle_sleep_s: float = 0.002):
        if engine.config.clock != "real":
            raise ValueError("AsyncServingServer needs SchedulerConfig("
                             "clock='real'); the virtual trace clock "
                             "cannot stamp live arrivals")
        self.engine = engine
        self.max_queue = max_queue
        self.submit_timeout_s = submit_timeout_s
        self.idle_sleep_s = idle_sleep_s
        engine.emit_hook = self._on_token      # worker thread
        engine.finish_hook = self._on_finish   # worker thread
        self._emissions: deque = deque()       # (rid, token | None)
        self._ingress: deque = deque()         # (ServeRequest, Future)
        self._streams: dict[int, asyncio.Queue] = {}
        self._space = asyncio.Condition()
        self._wake = asyncio.Event()
        self._rids = itertools.count()
        self._task: asyncio.Task | None = None
        self._draining = False
        self.completed: list[ServeRequest] = []

    # ------------------------------------------------------------------
    # engine hooks — called inside the worker thread mid-run_step; only
    # touch the thread-safe deque, never asyncio primitives

    def _on_token(self, req: ServeRequest, tok: int):
        self._emissions.append((req.rid, tok))

    def _on_finish(self, req: ServeRequest):
        self.completed.append(req)
        self._emissions.append((req.rid, None))

    # ------------------------------------------------------------------
    async def start(self):
        if self._task is None:
            self._draining = False
            self._task = asyncio.create_task(self._serve_loop())

    async def __aenter__(self):
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.drain()

    def _depth(self) -> int:
        return self.engine.pending() + len(self._ingress)

    def _reject(self, reason: str, rid: int, tenant: str):
        eng = self.engine
        eng.rejected_total += 1
        if eng.obs.enabled:
            eng.obs.metrics.counter(
                "serve_requests_rejected_total",
                "requests rejected at submit (never fits / bounded "
                "queue full)").inc(1, reason=reason, tenant=tenant)
        raise RequestRejected(reason, rid)

    async def submit(self, prompt, max_new_tokens: int = 32,
                     tenant: str = "default", priority: int = 1,
                     rid: int | None = None) -> ServeRequest:
        """Queue one request, awaiting while the bounded admission queue
        is full (backpressure).  Returns the live :class:`ServeRequest`
        handle — consume its tokens with :meth:`stream`.  Raises
        :class:`RequestRejected` when draining, on backpressure timeout,
        or when the request could never fit the engine."""
        if self._task is None and not self._draining:
            await self.start()    # a drained server needs explicit start()
        rid = next(self._rids) if rid is None else rid
        if self._draining:
            raise RequestRejected("draining", rid)
        req = ServeRequest(rid, np.asarray(prompt, np.int32),
                           int(max_new_tokens),
                           arrival_s=self.engine.now(),
                           tenant=tenant, priority=priority)
        deadline = (None if self.submit_timeout_s is None
                    else time.monotonic() + self.submit_timeout_s)
        async with self._space:
            while self._depth() >= self.max_queue and not self._draining:
                timeout = (None if deadline is None
                           else deadline - time.monotonic())
                if timeout is not None and timeout <= 0:
                    self._reject("backpressure_timeout", rid, tenant)
                try:
                    await asyncio.wait_for(self._space.wait(),
                                           timeout=timeout)
                except asyncio.TimeoutError:
                    self._reject("backpressure_timeout", rid, tenant)
            if self._draining:
                raise RequestRejected("draining", rid)
        fut = asyncio.get_running_loop().create_future()
        self._ingress.append((req, fut))
        self._wake.set()
        if not await fut:             # engine-level graceful rejection
            raise RequestRejected(req.rejected or "rejected", rid)
        return req

    async def stream(self, req: ServeRequest):
        """Async-iterate the request's verified tokens as they retire;
        ends (StopAsyncIteration) after the last token."""
        q = self._streams.get(req.rid)
        if q is None:
            return                    # already fully streamed
        while True:
            tok = await q.get()
            if tok is None:
                self._streams.pop(req.rid, None)
                return
            yield tok

    async def collect(self, req: ServeRequest) -> list:
        """Convenience: drain :meth:`stream` into a list."""
        return [tok async for tok in self.stream(req)]

    async def drain(self):
        """Graceful shutdown: reject new submissions, serve everything
        already queued or in flight, flush all streams, stop the loop."""
        self._draining = True
        self._wake.set()
        async with self._space:       # release backpressure waiters
            self._space.notify_all()
        if self._task is not None:
            await self._task
            self._task = None
        self.engine._close_window()   # seal the serving wall window

    # ------------------------------------------------------------------
    def _drain_ingress(self):
        """Move parked submissions into the engine queue (event-loop
        thread, worker idle — the engine is never touched from two
        threads at once)."""
        while self._ingress:
            req, fut = self._ingress.popleft()
            ok = self.engine.submit(req)
            if ok:
                self._streams[req.rid] = asyncio.Queue()
            if not fut.done():
                fut.set_result(ok)

    def _flush_emissions(self):
        tracker = self.engine.requests
        while self._emissions:
            rid, tok = self._emissions.popleft()
            q = self._streams.get(rid)
            if q is not None:
                q.put_nowait(tok)
                if tok is not None and tracker.enabled:
                    # stream delivery lands on the request's timeline
                    tracker.on_delivery(rid)

    async def _serve_loop(self):
        eng = self.engine
        while True:
            self._drain_ingress()
            if not eng.has_work():
                if self._draining:
                    break
                self._wake.clear()
                if not self._ingress:  # park until the next submit
                    await self._wake.wait()
                continue
            # one fused round off-thread: the event loop stays live for
            # submits/streams while the engine verifies+drafts
            await asyncio.to_thread(eng.run_step)
            self._flush_emissions()
            async with self._space:
                self._space.notify_all()
            if eng.idle_step:
                # queued arrivals lie in the future on the real clock
                await asyncio.sleep(self.idle_sleep_s)
            else:
                await asyncio.sleep(0)
        self._flush_emissions()

    # ------------------------------------------------------------------
    def tenant_report(self) -> dict:
        """Per-tenant serving digest over completed requests: counts,
        tokens, and TTFT / end-to-end latency percentiles."""
        from repro.serving.engine import latency_percentiles
        by_tenant: dict[str, list] = {}
        for r in self.completed:
            by_tenant.setdefault(r.tenant, []).append(r)
        return {
            t: {"requests": len(rs),
                "tokens": int(sum(len(r.result) for r in rs)),
                "preemptions": int(sum(r.preemptions for r in rs)),
                "ttft_s": latency_percentiles(rs, "ttft_s"),
                "e2e_s": latency_percentiles(rs, "latency_s")}
            for t, rs in sorted(by_tenant.items())}
