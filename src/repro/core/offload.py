"""Host<->HBM weight streaming: the TPU-native realization of the paper's
PCIe offloading (DESIGN.md §2).

* Target weights at rest live in ``pinned_host`` memory (the analogue of
  the paper's CPU DRAM tier); per layer-group slabs are copied into device
  memory *inside the jit'd step* via ``jax.device_put`` — XLA issues these
  as asynchronous copies that overlap with compute, which is exactly the
  paper's prefetch pipeline without any host threading.
* The KV cache may also live host-side, with decode attention computed
  under ``jax.experimental.compute_on('device_host')`` — the analogue of
  the paper's CPU-attention leg (§4.1.2).
* The draft model stays fully device-resident (the paper's "low-yield
  memory repurposing").

On this CPU-only container the memory spaces are both host RAM, but the
placement logic, copy schedule, and compiled HLO (with explicit
``memory_kind`` annotations) are the real thing.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.transformer import (forward_decoder, init_cache,
                                      logits_from_hidden)
from repro.obs import NULL_OBS

try:
    from jax.experimental.compute_on import compute_on
    HAS_COMPUTE_ON = True
except ImportError:  # pragma: no cover
    HAS_COMPUTE_ON = False


def _memory_kinds(device) -> set:
    try:
        return {m.kind for m in device.addressable_memories()}
    except Exception:  # pragma: no cover - very old jax
        return set()


def host_memory_kind(device=None) -> str:
    """The memory kind the host offload tier actually maps to on this
    backend: 'pinned_host' where exposed, else the device default (e.g.
    CPU on older jax only has 'unpinned_host')."""
    device = device or jax.devices()[0]
    if "pinned_host" in _memory_kinds(device):
        return "pinned_host"
    try:
        return device.default_memory().kind
    except Exception:  # pragma: no cover - very old jax
        return "device"


def _sharding(memory_kind: str, device=None):
    device = device or jax.devices()[0]
    if memory_kind not in _memory_kinds(device):
        # this backend/jax doesn't expose the tier (e.g. CPU on older jax
        # has only 'unpinned_host'): fall back to the default space — the
        # copy schedule stays identical, only the annotation is dropped
        return jax.sharding.SingleDeviceSharding(device)
    return jax.sharding.SingleDeviceSharding(device, memory_kind=memory_kind)


def put_host(tree):
    """Move a pytree to pinned host memory (the offload tier)."""
    return jax.device_put(tree, _sharding("pinned_host"))


def put_device(tree):
    return jax.device_put(tree, _sharding("device"))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def record_transfer(obs, tier: str, nbytes: float, seconds: float,
                    what: str = "transfer"):
    """Account one tier transfer in the metrics registry + trace.

    ``tier`` names the link direction ("h2d", "d2h"); bytes and seconds
    feed the ``transfer_bytes_total`` / ``transfer_seconds_total``
    counters the bench's utilization report reads, and a completed span
    lands on the matching trace track.
    """
    if not obs.enabled:
        return
    obs.metrics.counter(
        "transfer_bytes_total",
        "bytes moved across the offload link per tier").inc(
            float(nbytes), tier=tier)
    obs.metrics.counter(
        "transfer_seconds_total",
        "wall seconds spent on offload-link transfers per tier").inc(
            max(float(seconds), 0.0), tier=tier)
    if obs.tracer.enabled:
        t1 = time.perf_counter()
        obs.tracer.complete(tier, what, t1 - seconds, t1,
                            args={"bytes": float(nbytes)})


class OffloadedModel:
    """A model whose layer-group weights stream from host per step.

    ``params_host`` keeps ``layers`` in pinned host memory; embeddings +
    final norm (small, high reuse) stay device-resident, mirroring the
    placement plan's pinning priorities.
    """

    def __init__(self, cfg: ModelConfig, params: dict,
                 host_kv: bool = False, obs=None):
        self.cfg = cfg
        self.host_kv = host_kv and HAS_COMPUTE_ON
        self.obs = obs if obs is not None else NULL_OBS
        resident = {k: v for k, v in params.items() if k != "layers"}
        self.params_resident = put_device(resident)
        self.layers_host = put_host(params["layers"])
        record_transfer(self.obs, "d2h", tree_bytes(self.layers_host),
                        0.0, what="park_layers")

    # -- streamed forward ---------------------------------------------------

    def _assemble(self, layers_dev):
        p = dict(self.params_resident)
        p["layers"] = layers_dev
        return p

    @partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
    def _decode_jit(self, layers_dev, cache, tokens):
        params = self._assemble(layers_dev)
        logits, cache, pendings = M.decode(params, self.cfg, cache, tokens)
        return logits, cache, pendings

    def stream_layers(self):
        """host->device copy of the layer stack (the per-step stream).

        Dispatch is asynchronous; compute on previously-streamed data
        overlaps with this copy, which is the paper's prefetch.  With a
        fencing tracer the transfer is blocked to completion (honest
        link seconds); otherwise only dispatch cost is visible.
        """
        if not self.obs.enabled:
            return put_device(self.layers_host)
        t0 = time.perf_counter()
        layers = put_device(self.layers_host)
        if self.obs.tracer.enabled and self.obs.tracer.fence_spans:
            jax.block_until_ready(layers)
        record_transfer(self.obs, "h2d", tree_bytes(self.layers_host),
                        time.perf_counter() - t0, what="stream_layers")
        return layers

    def decode(self, cache, tokens):
        layers_dev = self.stream_layers()
        return self._decode_jit(layers_dev, cache, tokens)

    def prefill(self, tokens, cache, encoder_frames=None):
        layers_dev = self.stream_layers()
        params = self._assemble(layers_dev)
        return jax.jit(M.prefill, static_argnums=(1,))(
            params, self.cfg, tokens, cache)

    def streamed_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.layers_host))


# ---------------------------------------------------------------------------
# host-offloaded decode attention (the CPU-attention analogue)


def host_attention_direct(q, k, v, mask, scale):
    """Decode attention computed in host memory space.

    Used by the single-chip offload engine when the KV cache is
    host-resident: the score/softmax/PV chain executes under
    ``compute_on('device_host')`` so only q (tiny) and the output cross
    the host link — the KV cache itself never moves, exactly like the
    paper's CPU attention.
    """
    from repro.models.attention import attention_direct
    if not HAS_COMPUTE_ON:
        return attention_direct(q, k, v, mask, scale)
    with compute_on("device_host"):
        out = attention_direct(q, k, v, mask, scale)
    return out
