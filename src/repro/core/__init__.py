"""SpecOffload core: the paper's contribution as composable JAX modules.

- ``spec_decode``  — draft-then-verify speculative decoding (+ Appendix A.1
  acceptance model, with the Eq. 12 erratum corrected).
- ``interleave``   — the dual-batch Interleaved Batch Pipeline (§4.1).
- ``placement``    — Adaptive Tensor Placement across HBM/host/disk (§4.2).
- ``planner``      — ParaSpec policy planner (§4.3).
- ``offload``      — host<->HBM weight streaming with memory_kind tiers.
- ``pipeline``     — SpecOffloadEngine tying it all together (§3).
"""
from repro.core.interleave import (BatchState, InterleavedPipeline,
                                   RoundOutput, fused_verify_and_draft)
from repro.core.pipeline import SpecOffloadEngine
from repro.core.placement import PlacementPlan, plan_placement
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.core.spec_decode import (expected_generated, greedy_acceptance,
                                    sampled_acceptance, spec_round)

__all__ = [
    "BatchState", "InterleavedPipeline", "RoundOutput",
    "fused_verify_and_draft", "SpecOffloadEngine",
    "PlacementPlan", "plan_placement", "ParaSpecPlanner", "Policy",
    "Workload", "expected_generated", "greedy_acceptance",
    "sampled_acceptance", "spec_round",
]
