"""Interleaved Batch Pipeline (paper §4.1): dual-batch rotation.

The paper runs two batches in anti-phase: in slot t_n the *target* verifies
batch 1 while the *draft* generates candidates for batch 0; the roles swap
in t_{n+1}.  On GPU this needs two processes + shared memory (paper App.
A.2); in JAX the same concurrency is expressed as ONE fused jit step that
contains both computations — XLA schedules the draft model's matmuls into
the slack left by the target's streamed-weight copies (DESIGN.md §2).

``InterleavedPipeline.step()`` therefore performs, per call:

    verify(target, batch_V)   +   draft_generate(draft, batch_D)

and swaps the roles afterwards.  A warm-up call drafts for batch 0 only
(slot t_0 of the paper's Figure 4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.spec_decode import (draft_generate, greedy_acceptance,
                                    rollback_draft)
from repro.models import model as M


@dataclass
class BatchState:
    """Per-interleaved-batch decoding state."""
    target_cache: dict
    draft_cache: dict
    t_next: jax.Array            # (B,) last committed token (not yet fed)
    drafts: jax.Array | None     # (B, m) candidates awaiting verification
    draft_pendings: list | None  # rollback info for the draft steps
    emitted: list                # python-side: list of (tokens, n_emitted)


def fused_verify_and_draft(target_params, target_cfg: ModelConfig,
                           draft_params, draft_cfg: ModelConfig,
                           verify_state: dict, draft_state: dict,
                           n_cand: int, mesh=None):
    """The fused step: target verifies batch V's drafts while the draft
    model generates candidates for batch D — one XLA program.

    verify_state: {target_cache, t_next, drafts}
    draft_state:  {draft_cache, t_next}
    Returns (verify_out, draft_out) where verify_out carries acceptance
    results and draft_out carries new candidates.
    """
    # --- target side: verify batch V
    v_in = jnp.concatenate([verify_state["t_next"][:, None],
                            verify_state["drafts"]], axis=1)
    tlogits, tcache, tpend = M.decode(
        target_params, target_cfg, verify_state["target_cache"], v_in, mesh)
    a, nxt, n_commit = greedy_acceptance(verify_state["drafts"], tlogits)
    tcache = M.commit(target_cfg, tcache, tpend, n_commit, n_cand + 1)

    # --- draft side: generate for batch D (independent compute, same program)
    drafts, dlogits, dcache, dpend = draft_generate(
        draft_params, draft_cfg, draft_state["draft_cache"],
        draft_state["t_next"], n_cand, mesh)

    m = verify_state["drafts"].shape[1]
    out = jnp.where(jnp.arange(m)[None, :] < a[:, None],
                    verify_state["drafts"], 0)
    out = jnp.concatenate([out, jnp.zeros_like(a[:, None])], axis=1)
    out = jax.vmap(lambda row, i, t: row.at[i].set(t))(out, a, nxt)

    verify_out = {"target_cache": tcache, "tokens": out, "n_emitted": a + 1,
                  "t_next": nxt, "n_accept": a}
    draft_out = {"drafts": drafts, "draft_cache": dcache,
                 "pendings": dpend}
    return verify_out, draft_out


class InterleavedPipeline:
    """Runs the dual-batch rotation until every sequence has ``gen_len``
    tokens.  Pure orchestration — all heavy work happens in jitted steps."""

    def __init__(self, target_params, target_cfg, draft_params, draft_cfg,
                 n_cand: int, mesh=None):
        self.tp, self.tcfg = target_params, target_cfg
        self.dp, self.dcfg = draft_params, draft_cfg
        self.n_cand = n_cand
        self.mesh = mesh
        self._fused = jax.jit(
            fused_verify_and_draft,
            static_argnames=("target_cfg", "draft_cfg", "n_cand", "mesh"))
        self._draft_only = jax.jit(
            draft_generate, static_argnames=("cfg", "n_cand", "mesh"))
        self._rollback = jax.jit(
            rollback_draft, static_argnames=("cfg",))

    def run(self, states: list, gen_len: int, max_rounds: int = 10_000):
        """states: two BatchState entries (prefilled).  Mutates/returns
        them with ``emitted`` filled until each batch has gen_len tokens."""
        s0, s1 = states
        # warm-up (t_0 of Fig. 4): draft generates for batch 0
        d, _, dc, pend = self._draft_only(self.dp, self.dcfg, s0.draft_cache,
                                          s0.t_next, self.n_cand)
        s0.drafts, s0.draft_cache, s0.draft_pendings = d, dc, pend

        import numpy as np

        def total(st):
            """Guaranteed tokens so far = sum of per-round minima."""
            return int(sum(np.min(np.asarray(n)) for _, n in st.emitted))

        verify, gen = s0, s1
        rounds = 0
        while rounds < max_rounds:
            if total(s0) >= gen_len and total(s1) >= gen_len:
                break
            vstate = {"target_cache": verify.target_cache,
                      "t_next": verify.t_next, "drafts": verify.drafts}
            dstate = {"draft_cache": gen.draft_cache, "t_next": gen.t_next}
            vout, dout = self._fused(self.tp, self.tcfg, self.dp, self.dcfg,
                                     vstate, dstate, self.n_cand, self.mesh)
            # batch V: commit + roll its draft cache back to acceptance
            verify.target_cache = vout["target_cache"]
            verify.draft_cache = self._rollback(
                self.dcfg, verify.draft_cache, verify.draft_pendings,
                vout["n_emitted"])
            verify.t_next = vout["t_next"]
            verify.drafts, verify.draft_pendings = None, None
            verify.emitted.append((np.asarray(vout["tokens"]),
                                   np.asarray(vout["n_emitted"])))
            # batch D: stash fresh drafts
            gen.drafts = dout["drafts"]
            gen.draft_cache = dout["draft_cache"]
            gen.draft_pendings = dout["pendings"]
            # rotate roles (t_{n+1} of Fig. 4)
            verify, gen = gen, verify
            rounds += 1
        return s0, s1, rounds
