"""Interleaved Batch Pipeline (paper §4.1): dual-batch rotation.

The paper runs two batches in anti-phase: in slot t_n the *target* verifies
batch 1 while the *draft* generates candidates for batch 0; the roles swap
in t_{n+1}.  On GPU this needs two processes + shared memory (paper App.
A.2); in JAX the same concurrency is expressed as ONE fused jit step that
contains both computations — XLA schedules the draft model's matmuls into
the slack left by the target's streamed-weight copies (DESIGN.md §2).

Stepwise API (continuous-batching ready)
----------------------------------------
The pipeline is externally drivable, one rotation round at a time:

* :meth:`InterleavedPipeline.warmup` — slot t_0 of the paper's Figure 4:
  draft candidates for one batch so it can be verified next round.
* :meth:`InterleavedPipeline.step` — one fused round: verify the batch
  that holds drafts while drafting for the other; returns a
  :class:`RoundOutput` with per-sequence emitted tokens.  The caller owns
  the rotation (swap the two states between calls) and may mutate
  per-slot state *between* steps — the verified batch's ``drafts`` is
  ``None`` on return, which is the safe window for a scheduler to retire
  finished sequences and splice newly prefilled ones into freed cache
  slots (see :mod:`repro.serving.engine`).
* :meth:`InterleavedPipeline.run` — the original blocking loop, now a
  thin driver over ``warmup`` + ``step``.

All shapes inside ``step`` are fixed by ``(batch, n_cand)``, so the fused
jit program compiles exactly once per pipeline regardless of how many
sequences retire or join across rounds (``trace_counts`` exposes the
compile tally for tests).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.spec_decode import (draft_generate, draft_tree_generate,
                                    greedy_acceptance, rollback_draft,
                                    tree_commit_cache, tree_greedy_acceptance,
                                    tree_n_nodes, tree_spec, tree_supported)
from repro.models import model as M
from repro.obs import NULL_OBS


@dataclass
class BatchState:
    """Per-interleaved-batch decoding state."""
    target_cache: dict
    draft_cache: dict
    t_next: jax.Array            # (B,) last committed token (not yet fed)
    drafts: jax.Array | None     # (B, m) candidates awaiting verification
    draft_pendings: list | None  # rollback info for the draft steps
    emitted: list                # python-side: list of (tokens, n_emitted)


@dataclass
class RoundOutput:
    """Host-side result of one verified rotation round (one batch)."""
    tokens: np.ndarray           # (B, m+1) output slots (d_1..d_a, bonus, 0s)
    n_emitted: np.ndarray        # (B,) in [1, m+1]: valid prefix of tokens
    n_accept: np.ndarray         # (B,) accepted draft tokens this round
    # wall interval of the whole fused round (perf_counter seconds),
    # measured unconditionally (two clock reads) so request-scoped
    # timelines can attribute decode time without the span tracer on
    t0: float = 0.0
    t1: float = 0.0


def fused_verify_and_draft(target_params, target_cfg: ModelConfig,
                           draft_params, draft_cfg: ModelConfig,
                           verify_state: dict, draft_state: dict,
                           n_cand: int, mesh=None):
    """The fused step: target verifies batch V's drafts while the draft
    model generates candidates for batch D — one XLA program.

    verify_state: {target_cache, t_next, drafts}
    draft_state:  {draft_cache, t_next}
    Returns (verify_out, draft_out) where verify_out carries acceptance
    results and draft_out carries new candidates.
    """
    # --- target side: verify batch V
    v_in = jnp.concatenate([verify_state["t_next"][:, None],
                            verify_state["drafts"]], axis=1)
    tlogits, tcache, tpend = M.decode(
        target_params, target_cfg, verify_state["target_cache"], v_in, mesh)
    a, nxt, n_commit = greedy_acceptance(verify_state["drafts"], tlogits)
    tcache = M.commit(target_cfg, tcache, tpend, n_commit, n_cand + 1)

    # --- draft side: generate for batch D (independent compute, same program)
    drafts, dlogits, dcache, dpend = draft_generate(
        draft_params, draft_cfg, draft_state["draft_cache"],
        draft_state["t_next"], n_cand, mesh)

    m = verify_state["drafts"].shape[1]
    out = jnp.where(jnp.arange(m)[None, :] < a[:, None],
                    verify_state["drafts"], 0)
    out = jnp.concatenate([out, jnp.zeros_like(a[:, None])], axis=1)
    out = jax.vmap(lambda row, i, t: row.at[i].set(t))(out, a, nxt)

    verify_out = {"target_cache": tcache, "tokens": out, "n_emitted": a + 1,
                  "t_next": nxt, "n_accept": a}
    draft_out = {"drafts": drafts, "draft_cache": dcache,
                 "pendings": dpend}
    return verify_out, draft_out


def fused_tree_verify_and_draft(target_params, target_cfg: ModelConfig,
                                draft_params, draft_cfg: ModelConfig,
                                verify_state: dict, draft_state: dict,
                                branching: tuple, mesh=None):
    """Tree-mode fused step: the target verifies batch V's speculation
    tree (ancestor-masked, one forward over all ``n_nodes`` buffer rows)
    while the draft expands a fresh tree for batch D — one XLA program.

    verify_state: {target_cache, draft_cache, t_next, drafts} where
    ``drafts`` is the (B, N) BFS token buffer (row 0 == t_next).  Unlike
    the chain path there is no separate rollback call: both of batch V's
    caches are committed by accepted-path compaction *inside* the fused
    program (:func:`tree_commit_cache`), keeping the round at exactly one
    dispatch per rotation.
    """
    branching = tuple(branching)
    n_nodes = tree_n_nodes(branching)
    # --- target side: verify batch V's tree
    tlogits, tcache, _ = M.decode(
        target_params, target_cfg, verify_state["target_cache"],
        verify_state["drafts"], mesh, spec_tree=tree_spec(branching))
    a, nxt, out, path_idx = tree_greedy_acceptance(
        verify_state["drafts"], tlogits, branching)
    tcache = tree_commit_cache(target_cfg, tcache, path_idx, a, branching)
    vdcache = tree_commit_cache(draft_cfg, verify_state["draft_cache"],
                                path_idx, a, branching, pos_offset=n_nodes)

    # --- draft side: expand a tree for batch D (independent compute)
    drafts, _, dcache = draft_tree_generate(
        draft_params, draft_cfg, draft_state["draft_cache"],
        draft_state["t_next"], branching, mesh)

    verify_out = {"target_cache": tcache, "draft_cache": vdcache,
                  "tokens": out, "n_emitted": a + 1, "t_next": nxt,
                  "n_accept": a}
    draft_out = {"drafts": drafts, "draft_cache": dcache}
    return verify_out, draft_out


class InterleavedPipeline:
    """Dual-batch rotation, drivable one round at a time.

    Pure orchestration — all heavy work happens in jitted steps whose
    shapes depend only on ``(batch, n_cand)``.  ``trace_counts`` records
    how many times each jitted entry point was (re)traced; a scheduler
    that keeps shapes stable should see ``trace_counts['fused'] == 1``
    for the whole serving lifetime.
    """

    def __init__(self, target_params, target_cfg, draft_params, draft_cfg,
                 n_cand: int, mesh=None, obs=None, tree=None):
        self.tp, self.tcfg = target_params, target_cfg
        self.dp, self.dcfg = draft_params, draft_cfg
        self.n_cand = n_cand
        self.tree = tuple(tree) if tree is not None else None
        self.mesh = mesh
        self.obs = obs if obs is not None else NULL_OBS
        self.trace_counts = {"fused": 0, "draft": 0, "rollback": 0}
        self._exported_traces = {k: 0 for k in self.trace_counts}
        if self.tree is not None:
            for name, cfg in (("target", target_cfg), ("draft", draft_cfg)):
                if not tree_supported(cfg):
                    raise ValueError(
                        f"tree speculation requires an all-attention "
                        f"decoder-only {name} model (layer_pattern="
                        f"{cfg.layer_pattern!r})")
            tree_n_nodes(self.tree)          # validates shape and node cap
            self._fused = jax.jit(
                self._counted("fused", fused_tree_verify_and_draft),
                static_argnames=("target_cfg", "draft_cfg", "branching",
                                 "mesh"))
            self._draft_only = jax.jit(
                self._counted("draft", draft_tree_generate),
                static_argnames=("cfg", "branching", "mesh",
                                 "collect_logits"))
            self._rollback = None            # commit happens inside fused
            return
        self._fused = jax.jit(
            self._counted("fused", fused_verify_and_draft),
            static_argnames=("target_cfg", "draft_cfg", "n_cand", "mesh"))
        self._draft_only = jax.jit(
            self._counted("draft", draft_generate),
            static_argnames=("cfg", "n_cand", "mesh"))
        self._rollback = jax.jit(
            self._counted("rollback", rollback_draft),
            static_argnames=("cfg",))

    def _counted(self, name, fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            self.trace_counts[name] += 1   # runs only while tracing
            return fn(*args, **kwargs)
        return wrapper

    def export_trace_counts(self, registry) -> None:
        """Sync ``trace_counts`` into ``pipeline_traces_total{entry=...}``
        counters (delta-based: safe to call repeatedly).  A shape-stable
        serving run must report ``entry="fused"`` == 1 through this path
        (regression-tested in tests/test_obs.py)."""
        ctr = registry.counter(
            "pipeline_traces_total",
            "jit (re)traces per pipeline entry point; fused must stay 1")
        for entry, n in self.trace_counts.items():
            delta = n - self._exported_traces[entry]
            if delta:
                ctr.inc(delta, entry=entry)
                self._exported_traces[entry] = n
            elif n == 0:
                ctr.inc(0, entry=entry)   # materialize the zero series

    # ------------------------------------------------------------------
    def warmup(self, state: BatchState) -> None:
        """Slot t_0 (Fig. 4): draft candidates for ``state`` so the next
        :meth:`step` can verify it.  No-op if drafts are already staged."""
        if state.drafts is not None:
            return
        with self.obs.tracer.span("draft_generate", "warmup",
                                  cat="device") as sp:
            if self.tree is not None:
                d, _, dc = self._draft_only(self.dp, self.dcfg,
                                            state.draft_cache,
                                            state.t_next, self.tree)
                pend = None
            else:
                d, _, dc, pend = self._draft_only(self.dp, self.dcfg,
                                                  state.draft_cache,
                                                  state.t_next, self.n_cand)
            sp.fence(d)
        state.drafts, state.draft_cache, state.draft_pendings = d, dc, pend

    def step(self, verify: BatchState, gen: BatchState,
             record: bool = True) -> RoundOutput:
        """One rotation round: verify ``verify``'s staged drafts while
        drafting fresh candidates for ``gen`` (one fused XLA program).

        Mutates both states in place; on return ``verify.drafts is None``
        (the safe window for slot surgery) and ``gen`` holds new drafts.
        ``record=False`` skips appending to ``verify.emitted`` — use it
        when the caller does its own per-slot bookkeeping, so a
        long-running server doesn't grow the emitted log unboundedly.
        """
        assert verify.drafts is not None, "verify batch has no staged drafts"
        assert gen.drafts is None, "gen batch already holds drafts"
        t_round0 = time.perf_counter()
        vstate = {"target_cache": verify.target_cache,
                  "t_next": verify.t_next, "drafts": verify.drafts}
        if self.tree is not None:
            vstate["draft_cache"] = verify.draft_cache
        dstate = {"draft_cache": gen.draft_cache, "t_next": gen.t_next}
        tr = self.obs.tracer
        # The fused call is ONE XLA program doing both phases; record it
        # as anti-phase twins — a verify span plus a mirrored draft span
        # over the same interval (bubble accounting unions the overlap,
        # so device-busy time is not double counted).
        with tr.span("target_verify", "verify(fused)", cat="device") as sp:
            vout, dout = self._fused(self.tp, self.tcfg, self.dp, self.dcfg,
                                     vstate, dstate,
                                     self.tree if self.tree is not None
                                     else self.n_cand, self.mesh)
            sp.fence((vout, dout))
        if tr.enabled:
            tr.complete("draft_generate", "draft(fused)", sp.t0, sp.t1,
                        cat="device")
        verify.target_cache = vout["target_cache"]
        if self.tree is not None:
            # batch V's draft cache was compacted to the accepted path
            # inside the fused program — no separate rollback dispatch.
            verify.draft_cache = vout["draft_cache"]
        else:
            # batch V: commit + roll its draft cache back to acceptance
            with tr.span("rollback", "rollback", cat="device") as rb:
                verify.draft_cache = rb.fence(self._rollback(
                    self.dcfg, verify.draft_cache, verify.draft_pendings,
                    vout["n_emitted"]))
        verify.t_next = vout["t_next"]
        verify.drafts, verify.draft_pendings = None, None
        out = RoundOutput(tokens=np.asarray(vout["tokens"]),
                          n_emitted=np.asarray(vout["n_emitted"]),
                          n_accept=np.asarray(vout["n_accept"]),
                          t0=t_round0, t1=time.perf_counter())
        if record:
            verify.emitted.append((out.tokens, out.n_emitted))
        # batch D: stash fresh drafts
        gen.drafts = dout["drafts"]
        gen.draft_cache = dout["draft_cache"]
        gen.draft_pendings = dout.get("pendings")
        return out

    def run(self, states: list, gen_len: int, max_rounds: int = 10_000):
        """Blocking driver: rotate until every sequence has ``gen_len``
        tokens.  states: two BatchState entries (prefilled); mutated and
        returned with ``emitted`` filled."""
        s0, s1 = states
        self.warmup(s0)

        def total(st):
            """Guaranteed tokens so far = sum of per-round minima."""
            return int(sum(np.min(np.asarray(n)) for _, n in st.emitted))

        verify, gen = s0, s1
        rounds = 0
        while rounds < max_rounds:
            if total(s0) >= gen_len and total(s1) >= gen_len:
                break
            self.step(verify, gen)
            verify, gen = gen, verify        # rotate roles (t_{n+1}, Fig. 4)
            rounds += 1
        return s0, s1, rounds
