"""ParaSpec Planner (paper §4.3, Appendix A.1).

Maximizes throughput = N_generated / T_generation over the policy
``(bs_prefill, bs_decode, bs_draft, n_cand)`` subject to peak-accelerator-
memory constraints, using the paper's latency/memory model:

  T_generation = T_prefill + T_decoding                      (13)
  T_prefill    = ceil(bs / bs_prefill) * T_prefill_step      (14)  I/O-bound:
  T_prefill_step ~ T_para_C2G (+ compute)                    (15)
  T_decoding   = n_iter * max(T_target_decode, T_draft)      (16)
  T_draft      = ceil(bs/bs_draft) * [T_dprefill + (n_cand-1) T_ddecode] (17)
  T_target     = n_layer * [max(T_attn_host, T_ffn_stream) + T_ffn_gpu] (18)
  T_attn_host  = n_cand_tokens * bs * t_attn_per_token       (19)
  E[n_generated] per Eq. (12) with per-token acceptance p.

Memory (20)-(22): prefill = target params resident + bs_prefill KV slice;
decode = streamed FFN slab + draft params + draft KV.

The planner is pure Python/numpy (no jax) so it can run in the launcher
before any device work, exactly as the paper's offline phase does.

Beyond the paper, :class:`Workload` carries an *effective occupancy* term
(fraction of in-flight batch slots holding live requests).  Prefill and
host-attention KV traffic are modelled per live sequence while the
streamed-FFN decode round is paid per slot, so the optimal policy shifts
with occupancy — the continuous-batching scheduler re-runs :meth:`search`
online when its measured occupancy drifts (see
:meth:`repro.serving.engine.ServingEngine`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.spec_decode import (expected_generated,
                                    expected_generated_tree, tree_layout,
                                    tree_n_nodes)
from repro.sim.hardware import HardwareSpec

@dataclass(frozen=True)
class Policy:
    """The gray tuple of the paper's tables (+ optional tree shape)."""
    bs_prefill: int
    bs_decode: int          # per interleaved batch (total = 2x)
    bs_draft: int
    n_cand: int             # draft max new tokens (chain mode)
    tree: tuple | None = None  # speculation-tree branching per depth;
                               # None = linear chain of n_cand drafts

    def astuple(self):
        base = (self.bs_prefill, self.bs_decode, self.bs_draft, self.n_cand)
        return base if self.tree is None else base + (self.tree,)


@dataclass
class Workload:
    prompt_len: int          # S_avg of the dataset
    gen_len: int             # tokens to generate per sequence
    accept_prob: float = 0.7 # per-token draft acceptance probability p
    occupancy: float = 1.0   # effective batch-slot occupancy in (0, 1]:
                             # fraction of in-flight slots holding live
                             # requests (continuous batching keeps this
                             # near 1; padded-wave draining does not)
    kv_bytes_per_seq: float | None = None
                             # measured resident target-KV bytes per live
                             # sequence (the serving engine feeds its
                             # paged-allocator average here); None falls
                             # back to the analytic ctx * bytes/token
                             # model, which over-states int8/paged caches


# ---------------------------------------------------------------------------
# model byte/flop accounting helpers


def layer_ffn_bytes(cfg: ModelConfig, bytes_per: int = 2) -> float:
    """Streamed-per-layer FFN bytes (all experts for MoE — the stream unit)."""
    return cfg._ffn_params() * bytes_per


def layer_attn_bytes(cfg: ModelConfig, bytes_per: int = 2) -> float:
    hd = cfg.head_dim
    n = (cfg.d_model * cfg.n_heads * hd + 2 * cfg.d_model * cfg.n_kv_heads * hd
         + cfg.n_heads * hd * cfg.d_model)
    return n * bytes_per


def kv_bytes_per_token(cfg: ModelConfig, bytes_per: int = 2) -> float:
    return 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * bytes_per


def stored_kv_bytes_per_seq(cfg: ModelConfig, context: int, *,
                            block_size: int | None = None,
                            quant: bool = False,
                            bytes_per: int = 2) -> float:
    """Resident full-attention KV bytes one sequence holds at ``context``
    tokens in the *serving* cache, as actually stored:

    * ``quant`` — int8 values (1 byte/elem) plus a 4-byte f32 absmax
      scale per (token, kv-head) for each of K and V;
    * ``block_size`` — paged storage rounds the context up to the block
      grid (internal fragmentation of the last block).
    """
    tokens = context if block_size is None \
        else -(-context // block_size) * block_size
    elems = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
    if quant:
        per_tok = elems + 2 * cfg.n_layers * cfg.n_kv_heads * 4
    else:
        per_tok = elems * bytes_per
    return float(tokens * per_tok)


def attn_flops_per_token(cfg: ModelConfig, context: int) -> float:
    """Decode attention FLOPs for one query token against `context` KV."""
    return 4 * cfg.n_layers * cfg.n_heads * cfg.head_dim * context


def dense_flops_per_token(cfg: ModelConfig) -> float:
    """Matmul FLOPs per token (active params only for MoE)."""
    return 2 * cfg.active_param_count()


# ---------------------------------------------------------------------------


@dataclass
class PlanReport:
    policy: Policy
    throughput: float
    t_prefill: float
    t_decode: float
    t_target: float
    t_draft: float
    expected_tokens: float
    peak_mem_prefill: float
    peak_mem_decode: float
    feasible: bool
    detail: dict = field(default_factory=dict)


class ParaSpecPlanner:
    """Offline profiling model + online policy search."""

    def __init__(self, target: ModelConfig, draft: ModelConfig,
                 hw: HardwareSpec, bytes_per_param: int = 2, obs=None):
        self.target = target
        self.draft = draft
        self.hw = hw
        self.bp = bytes_per_param
        if obs is None:
            from repro.obs import NULL_OBS
            obs = NULL_OBS
        self.obs = obs

    # -- latency model -----------------------------------------------------

    def evaluate(self, pol: Policy, wl: Workload) -> PlanReport:
        cfg, dcfg, hw = self.target, self.draft, self.hw
        bs = pol.bs_decode * 2          # dual-batch rotation: total in flight
        m = pol.n_cand
        # tokens the target forwards per verify pass: the whole flattened
        # tree buffer in tree mode, the chain's n_cand+1 otherwise
        n_verify = tree_n_nodes(pol.tree) if pol.tree else m + 1
        # Effective occupancy: fraction of in-flight slots holding live
        # requests.  Prefill and host-attention KV traffic are paid per
        # *live* sequence; the streamed-FFN decode round is paid per
        # *slot* (dead slots still ride the fused step).  This makes the
        # best policy occupancy-dependent, so the serving engine re-runs
        # the search online when measured occupancy drifts.
        occ = min(max(wl.occupancy, 1e-6), 1.0)
        n_live = bs * occ

        # ---- prefill (Eqs. 14-15): stream whole model once per microbatch
        stream_bytes = cfg.param_bytes(self.bp)
        t_prefill_step = stream_bytes / hw.h2d_bw + (
            wl.prompt_len * pol.bs_prefill * dense_flops_per_token(cfg)
            / hw.accel_flops)
        # KV cache written on accelerator then shipped to host (Table 3 P row)
        kv_ship = (wl.prompt_len * kv_bytes_per_token(cfg, self.bp)
                   / hw.d2h_bw)
        t_prefill = math.ceil(n_live / pol.bs_prefill) * t_prefill_step \
            + n_live * kv_ship

        # ---- decode round (Eqs. 16-19)
        ctx = wl.prompt_len + wl.gen_len / 2
        # host attention (Eq. 19): CPU attention is DRAM-bandwidth bound —
        # each round streams the whole KV working set once (plus compute)
        attn_flops = (n_verify * pol.bs_decode * occ
                      * attn_flops_per_token(cfg, int(ctx)))
        # KV traffic per live sequence: prefer the *measured* resident
        # bytes (the serving engine's paged allocator reports its block-
        # granular average, which reflects int8 storage and block
        # fragmentation) over the analytic bf16-contiguous model
        kv_seq = (wl.kv_bytes_per_seq if wl.kv_bytes_per_seq
                  else ctx * kv_bytes_per_token(cfg, self.bp))
        kv_read = pol.bs_decode * occ * kv_seq
        t_attn_host = max(attn_flops / hw.host_flops,
                          kv_read / (hw.host_mem_bw * hw.host_attn_eff))
        # per-layer FFN stream vs host attention overlap (Eq. 18)
        ffn_per_layer = layer_ffn_bytes(cfg, self.bp)
        t_ffn_stream = cfg.n_layers * ffn_per_layer / hw.h2d_bw
        t_ffn_gpu = (n_verify * pol.bs_decode * dense_flops_per_token(cfg)
                     / hw.accel_flops)
        t_target = max(t_attn_host, t_ffn_stream) + t_ffn_gpu

        # draft generation for the other batch (Eq. 17).  The paper's draft
        # runs *full-sequence* autoregressive inference each round (App.
        # A.2: no persistent draft KV across rounds), so each sub-batch
        # pays a ctx-long prefill plus (m-1) decode steps.  (Our JAX engine
        # keeps a rollback-able draft cache — recorded as a beyond-paper
        # optimization in EXPERIMENTS.md §Perf.)
        d_flops = dense_flops_per_token(dcfg)
        d_attn = attn_flops_per_token(dcfg, int(ctx))
        d_bytes = dcfg.param_bytes(self.bp)
        pf = hw.accel_flops_prefill or hw.accel_flops * 1.33
        t_dprefill = max(pol.bs_draft * ctx * d_flops / pf,
                         d_bytes / hw.accel_mem_bw)
        t_ddecode = max(pol.bs_draft * (d_flops + d_attn) / hw.accel_flops,
                        d_bytes / hw.accel_mem_bw)
        if pol.tree:
            # one masked decode pass per tree level; level d feeds
            # prod(branching[:d]) tokens, each either compute- or
            # weight-bandwidth-bound like the chain's decode step
            widths = tree_layout(tuple(pol.tree))["level_sizes"][1:]
            t_levels = sum(
                max(pol.bs_draft * int(w) * (d_flops + d_attn)
                    / hw.accel_flops, d_bytes / hw.accel_mem_bw)
                for w in widths)
            t_draft = math.ceil(pol.bs_decode / pol.bs_draft) * (
                t_dprefill + t_levels)
        else:
            t_draft = math.ceil(pol.bs_decode / pol.bs_draft) * (
                t_dprefill + (m - 1) * t_ddecode)

        t_round = max(t_target, t_draft)
        e_n = (expected_generated_tree(wl.accept_prob, tuple(pol.tree))
               if pol.tree else expected_generated(wl.accept_prob, m))
        n_iter = math.ceil(wl.gen_len / e_n)
        # dual-batch rotation: the target pipeline serves the two
        # interleaved batches in alternating slots -> 2x n_iter slots
        t_decode = 2 * n_iter * t_round

        n_generated = n_live * wl.gen_len
        thr = n_generated / (t_prefill + t_decode)

        # ---- memory (Eqs. 20-22)
        v_prefill = cfg.param_bytes(self.bp) * min(
            1.0, hw.accel_mem_bytes / cfg.param_bytes(self.bp)) * 0 \
            + self._prefill_resident() \
            + pol.bs_prefill * wl.prompt_len * kv_bytes_per_token(cfg, self.bp)
        v_decode = (2 * ffn_per_layer          # current + prefetched layer
                    + dcfg.param_bytes(self.bp)
                    + pol.bs_draft * (wl.prompt_len + wl.gen_len)
                    * kv_bytes_per_token(dcfg, self.bp)
                    + self._act_bytes(pol, n_verify))
        feasible = (v_prefill <= hw.accel_mem_bytes
                    and v_decode <= hw.accel_mem_bytes
                    and cfg.param_bytes(self.bp) <= hw.host_mem_bytes
                    + hw.accel_mem_bytes)

        return PlanReport(
            policy=pol, throughput=thr, t_prefill=t_prefill,
            t_decode=t_decode, t_target=t_target, t_draft=t_draft,
            expected_tokens=e_n, peak_mem_prefill=v_prefill,
            peak_mem_decode=v_decode, feasible=feasible,
            detail={"t_attn_host": t_attn_host, "t_ffn_stream": t_ffn_stream,
                    "t_ffn_gpu": t_ffn_gpu, "n_iter": n_iter,
                    "t_round": t_round})

    def _prefill_resident(self) -> float:
        """Layer slab resident during zig-zag prefill: 2 layers of params."""
        per_layer = (layer_attn_bytes(self.target, self.bp)
                     + layer_ffn_bytes(self.target, self.bp))
        return 2 * per_layer

    def _act_bytes(self, pol: Policy, n_verify: int) -> float:
        cfg = self.target
        return 4 * n_verify * pol.bs_decode * cfg.d_model * 4

    # -- search ------------------------------------------------------------

    def search(self, wl: Workload,
               bs_prefill_grid=(16, 32, 50, 64, 80, 96, 128),
               bs_decode_grid=(32, 64, 128, 160, 192, 256, 320),
               bs_draft_grid=(4, 5, 6, 8, 10, 16),
               n_cand_grid=(1, 2, 4, 6, 8)) -> PlanReport:
        """Exhaustive grid search (the paper's space is small)."""
        best = None
        with self.obs.tracer.span("planner", "policy_search") as sp:
            for bp_ in bs_prefill_grid:
                for bd in bs_decode_grid:
                    for bdr in bs_draft_grid:
                        if bdr > bd:
                            continue
                        for m in n_cand_grid:
                            rep = self.evaluate(Policy(bp_, bd, bdr, m), wl)
                            if not rep.feasible:
                                continue
                            if (best is None
                                    or rep.throughput > best.throughput):
                                best = rep
            if best is not None:
                sp.set("policy", str(best.policy.astuple()))
                sp.set("occupancy", wl.occupancy)
        if best is None:
            raise ValueError("no feasible policy — model too large for host+"
                             "accelerator memory")
        if self.obs.enabled:
            self.obs.tracer.instant(
                "planner", "replan",
                {"bs_prefill": best.policy.bs_prefill,
                 "bs_decode": best.policy.bs_decode,
                 "bs_draft": best.policy.bs_draft,
                 "n_cand": best.policy.n_cand,
                 "occupancy": wl.occupancy,
                 "modeled_throughput": best.throughput})
            self.obs.metrics.counter(
                "planner_searches_total",
                "ParaSpec policy searches (offline + online replans)"
            ).inc(1)
        return best

    def search_spec(self, wl: Workload, tree_grid=None,
                    node_budget: int = 16,
                    bs_draft_grid=(4, 5, 6, 8, 10, 16),
                    **search_kw) -> PlanReport:
        """Joint chain-vs-tree speculation search.

        Runs the chain :meth:`search` first, then re-evaluates the best
        chain policy's batch dimensions with every tree shape in
        ``tree_grid`` (sweeping ``bs_draft`` — tree levels shift the
        draft's compute/bandwidth balance).  ``node_budget`` caps the
        flattened buffer so a wide tree can't blow up the verify pass.
        At low acceptance rates extra siblings raise the chance *some*
        path survives each depth, so trees win; at high acceptance a deep
        chain is optimal and the chain policy comes back unchanged.
        """
        if tree_grid is None:
            tree_grid = TREE_GRID
        best = self.search(wl, **search_kw)
        base = best.policy
        for tree in tree_grid:
            tree = tuple(tree)
            if tree_n_nodes(tree) > node_budget:
                continue
            for bdr in bs_draft_grid:
                if bdr > base.bs_decode:
                    continue
                rep = self.evaluate(
                    Policy(base.bs_prefill, base.bs_decode, bdr,
                           len(tree), tree=tree), wl)
                if rep.feasible and rep.throughput > best.throughput:
                    best = rep
        if self.obs.enabled and best.policy.tree is not None:
            self.obs.tracer.instant(
                "planner", "replan_tree",
                {"tree": str(best.policy.tree),
                 "bs_draft": best.policy.bs_draft,
                 "modeled_throughput": best.throughput})
        return best


#: Tree shapes the online replanner considers (depth-major; every shape
#: stays under the 31-node ancestor-bitmask cap with plenty of margin).
TREE_GRID = ((2,), (3,), (4,), (2, 2), (3, 2), (4, 2), (2, 2, 2), (3, 3))
