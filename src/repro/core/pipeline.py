"""SpecOffloadEngine — the paper's full system (§3): offline placement +
online planning + the two-phase interleaved pipeline.

Usage (see examples/serve_spec_offload.py)::

    engine = SpecOffloadEngine(target_cfg, draft_cfg, hw=ENV1)
    engine.load(target_params, draft_params)
    out = engine.generate(prompts, gen_len=64)

Stepwise API
------------
``generate()`` is a convenience wrapper over three explicit phases, each
usable on its own (the continuous-batching scheduler in
:mod:`repro.serving.engine` drives them directly):

* :meth:`prefill_batch` — zig-zag microbatched prefill (§4.1.1) of a
  prompt batch into a fresh :class:`BatchState` (target + draft caches,
  first greedy token staged in ``t_next``).
* :meth:`decode_round` — one dual-batch rotation round (§4.1.2) via
  :class:`repro.core.interleave.InterleavedPipeline`; returns the
  verified batch's per-sequence tokens.
* :meth:`finalize` — assemble the per-round emission log of the two
  interleaved batches into a dense ``(B, gen_len)`` token array.

Phases
------
* **Prefill** (§4.1.1) — zig-zag microbatching: the prompt batch is split
  into ``bs_prefill`` chunks; each chunk runs a full prefill while the
  engine keeps only the streamed working set resident.  KV is then handed
  to the decode phase (host tier in the offloaded configuration).
* **Decode** (§4.1.2) — dual-batch rotation via
  :class:`repro.core.interleave.InterleavedPipeline`.

The engine is hardware-agnostic: on the CPU container it runs the real
algorithm end-to-end at small scale; placement/planner decisions use the
configured :class:`HardwareSpec`.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.interleave import (BatchState, InterleavedPipeline,
                                   RoundOutput)
from repro.core.placement import PlacementPlan, plan_placement
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.models import model as M
from repro.models.transformer import init_cache
from repro.obs import NULL_OBS
from repro.sim.hardware import ENV1, HardwareSpec


def required_cache_len(prompt_len: int, gen_len: int, n_cand: int) -> int:
    """Per-sequence KV capacity for a decode of ``gen_len`` tokens: the
    last speculative round can overshoot the target length, and the draft
    cache briefly holds ``n_cand + 1`` uncommitted positions before
    rollback.  Shared by generate() and the serving scheduler so their
    capacity checks can never diverge."""
    return prompt_len + gen_len + 3 * (n_cand + 1) + 4


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, gen_len)
    rounds: int
    accept_counts: list
    policy: Policy
    placement: PlacementPlan


class SpecOffloadEngine:
    def __init__(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
                 hw: HardwareSpec = ENV1, policy: Policy | None = None,
                 mesh=None, obs=None):
        self.tcfg = target_cfg
        self.dcfg = draft_cfg
        self.hw = hw
        self.mesh = mesh
        self.obs = obs if obs is not None else NULL_OBS
        self.policy = policy
        self.placement = plan_placement(target_cfg, draft_cfg, hw)
        self.tp = None
        self.dp = None
        self._prefill = jax.jit(M.prefill, static_argnums=(1,),
                                static_argnames=("mesh",))
        self._pipe: InterleavedPipeline | None = None

    # ------------------------------------------------------------------
    def load(self, target_params, draft_params):
        self.tp = target_params
        self.dp = draft_params
        self._pipe = None

    def init_from_seed(self, seed: int = 0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.load(M.init_params(self.tcfg, k1), M.init_params(self.dcfg, k2))

    def plan(self, prompt_len: int, gen_len: int,
             accept_prob: float = 0.7, occupancy: float = 1.0) -> Policy:
        if self.policy is not None:
            return self.policy
        planner = ParaSpecPlanner(self.tcfg, self.dcfg, self.hw,
                                  obs=self.obs)
        rep = planner.search(Workload(prompt_len, gen_len, accept_prob,
                                      occupancy))
        self.policy = rep.policy
        return self.policy

    # ------------------------------------------------------------------
    def _prefill_zigzag(self, params, cfg, tokens: jax.Array,
                        bs_prefill: int, max_len: int):
        """Microbatched prefill (zig-zag §4.1.1): the batch is processed in
        ``bs_prefill`` chunks so only one chunk's activations + KV are live
        on the accelerator at a time; chunk caches are then concatenated
        (the paper ships them to host memory between chunks)."""
        b = tokens.shape[0]
        last_logits, caches = [], []
        for i in range(0, b, bs_prefill):
            chunk = tokens[i:i + bs_prefill]
            c = init_cache(cfg, chunk.shape[0], max_len)
            lg, c = self._prefill(params, cfg, chunk, c)
            last_logits.append(lg)
            caches.append(c)
        if len(caches) == 1:
            return last_logits[0], caches[0]
        return jnp.concatenate(last_logits, 0), _concat_caches(caches)

    # ------------------------------------------------------------------
    # stepwise API

    def prefill_batch(self, prompts: jax.Array, max_len: int,
                      bs_prefill: int | None = None) -> BatchState:
        """Zig-zag prefill of a ``(B, L)`` prompt batch into a fresh
        :class:`BatchState` with KV capacity ``max_len`` per sequence.

        The first greedy token (argmax over the prefill's last logits) is
        staged in ``t_next`` and recorded as the first emission, exactly
        as a target-only greedy decode would start.
        """
        assert self.tp is not None, "call load()/init_from_seed() first"
        bs_prefill = bs_prefill or max(1, prompts.shape[0])
        with self.obs.tracer.span("prefill", "zigzag_prefill",
                                  cat="device") as sp:
            lg, tc = self._prefill_zigzag(self.tp, self.tcfg, prompts,
                                          bs_prefill, max_len)
            _, dc = self._prefill_zigzag(self.dp, self.dcfg, prompts,
                                         bs_prefill, max_len)
            sp.fence((lg, tc, dc))
            sp.set("batch", int(prompts.shape[0]))
            sp.set("prompt_len", int(prompts.shape[1]))
        t0 = jnp.argmax(lg, -1)
        return BatchState(target_cache=tc, draft_cache=dc, t_next=t0,
                          drafts=None, draft_pendings=None,
                          emitted=[(np.asarray(t0)[:, None], 1)])

    def pipeline(self, n_cand: int, tree=None) -> InterleavedPipeline:
        """The (cached) dual-batch rotation pipeline for ``n_cand`` —
        or, when ``tree`` (a branching tuple) is given, the tree-mode
        pipeline with that speculation-tree shape."""
        assert self.tp is not None, "call load()/init_from_seed() first"
        tree = tuple(tree) if tree is not None else None
        if (self._pipe is None or self._pipe.n_cand != n_cand
                or self._pipe.tree != tree):
            self._pipe = InterleavedPipeline(self.tp, self.tcfg, self.dp,
                                             self.dcfg, n_cand, self.mesh,
                                             obs=self.obs, tree=tree)
        return self._pipe

    def decode_round(self, verify: BatchState, gen: BatchState,
                     n_cand: int, record: bool = True,
                     tree=None) -> RoundOutput:
        """One rotation round: verify ``verify``, draft for ``gen``.
        Swap the two states between calls to rotate roles; see
        :meth:`InterleavedPipeline.step` for the slot-surgery window."""
        pipe = self.pipeline(n_cand, tree=tree)
        pipe.warmup(verify)
        return pipe.step(verify, gen, record=record)

    def finalize(self, states: list, gen_len: int) -> tuple:
        """Assemble the two interleaved batches' emission logs into a
        dense ``(B_total, gen_len)`` array (+ per-round accept counts)."""
        widths = [int(np.asarray(st.emitted[0][0]).shape[0])
                  for st in states]
        out = np.zeros((sum(widths), gen_len), np.int32)
        accepts = []
        row0 = 0
        for st, width in zip(states, widths):
            fills = [list() for _ in range(width)]
            for toks, n in st.emitted:
                toks = np.asarray(toks)
                n = np.asarray(n) + np.zeros(toks.shape[0], np.int32)
                for r in range(toks.shape[0]):
                    fills[r].extend(toks[r, :int(n[r])].tolist())
                if toks.shape[1] > 1:
                    accepts.append(n - 1)
            for r, f in enumerate(fills):
                out[row0 + r] = (f + [0] * gen_len)[:gen_len]
            row0 += width
        return out, accepts

    # ------------------------------------------------------------------
    def generate(self, prompts: jax.Array, gen_len: int, n_cand: int = 4,
                 max_len: int | None = None) -> GenerationResult:
        """prompts (B, L) int32, B split into the two interleaved batches.

        Convenience wrapper: prefill both halves, rotate decode rounds
        until every sequence has ``gen_len`` tokens, finalize."""
        assert self.tp is not None, "call load()/init_from_seed() first"
        b, length = prompts.shape
        pol = self.policy or Policy(bs_prefill=max(1, b // 2),
                                    bs_decode=max(1, b // 2),
                                    bs_draft=max(1, b // 2), n_cand=n_cand)
        m = pol.n_cand
        max_len = max_len or required_cache_len(length, gen_len, m)

        half = b // 2
        states = [self.prefill_batch(bt, max_len, pol.bs_prefill)
                  for bt in (prompts[:half], prompts[half:])]

        pipe = self.pipeline(m)
        s0, s1, rounds = pipe.run(states, gen_len)

        out, accepts = self.finalize([s0, s1], gen_len)
        return GenerationResult(out, rounds, accepts, pol, self.placement)


def _concat_caches(caches):
    """Concat per-chunk caches over the batch axis (axis 1 for stacked
    layer leaves, axis 0 for 'pos')."""
    layers = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                          *[c["layers"] for c in caches])
    pos = jnp.concatenate([c["pos"] for c in caches], axis=0)
    return {"layers": layers, "pos": pos}
