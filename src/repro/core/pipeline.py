"""SpecOffloadEngine — the paper's full system (§3): offline placement +
online planning + the two-phase interleaved pipeline.

Usage (see examples/serve_spec_offload.py)::

    engine = SpecOffloadEngine(target_cfg, draft_cfg, hw=ENV1)
    engine.load(target_params, draft_params)
    out = engine.generate(prompts, gen_len=64)

Phases
------
* **Prefill** (§4.1.1) — zig-zag microbatching: the prompt batch is split
  into ``bs_prefill`` chunks; each chunk runs a full prefill while the
  engine keeps only the streamed working set resident.  KV is then handed
  to the decode phase (host tier in the offloaded configuration).
* **Decode** (§4.1.2) — dual-batch rotation via
  :class:`repro.core.interleave.InterleavedPipeline`.

The engine is hardware-agnostic: on the CPU container it runs the real
algorithm end-to-end at small scale; placement/planner decisions use the
configured :class:`HardwareSpec`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.interleave import BatchState, InterleavedPipeline
from repro.core.placement import PlacementPlan, plan_placement
from repro.core.planner import ParaSpecPlanner, Policy, Workload
from repro.models import model as M
from repro.models.transformer import init_cache
from repro.sim.hardware import ENV1, HardwareSpec


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, gen_len)
    rounds: int
    accept_counts: list
    policy: Policy
    placement: PlacementPlan


class SpecOffloadEngine:
    def __init__(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
                 hw: HardwareSpec = ENV1, policy: Policy | None = None,
                 mesh=None):
        self.tcfg = target_cfg
        self.dcfg = draft_cfg
        self.hw = hw
        self.mesh = mesh
        self.policy = policy
        self.placement = plan_placement(target_cfg, draft_cfg, hw)
        self.tp = None
        self.dp = None
        self._prefill = jax.jit(M.prefill, static_argnums=(1,),
                                static_argnames=("mesh",))

    # ------------------------------------------------------------------
    def load(self, target_params, draft_params):
        self.tp = target_params
        self.dp = draft_params

    def init_from_seed(self, seed: int = 0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.load(M.init_params(self.tcfg, k1), M.init_params(self.dcfg, k2))

    def plan(self, prompt_len: int, gen_len: int,
             accept_prob: float = 0.7) -> Policy:
        if self.policy is not None:
            return self.policy
        planner = ParaSpecPlanner(self.tcfg, self.dcfg, self.hw)
        rep = planner.search(Workload(prompt_len, gen_len, accept_prob))
        self.policy = rep.policy
        return self.policy

    # ------------------------------------------------------------------
    def _prefill_zigzag(self, params, cfg, tokens: jax.Array,
                        bs_prefill: int, max_len: int):
        """Microbatched prefill (zig-zag §4.1.1): the batch is processed in
        ``bs_prefill`` chunks so only one chunk's activations + KV are live
        on the accelerator at a time; chunk caches are then concatenated
        (the paper ships them to host memory between chunks)."""
        b = tokens.shape[0]
        last_logits, caches = [], []
        for i in range(0, b, bs_prefill):
            chunk = tokens[i:i + bs_prefill]
            c = init_cache(cfg, chunk.shape[0], max_len)
            lg, c = self._prefill(params, cfg, chunk, c)
            last_logits.append(lg)
            caches.append(c)
        if len(caches) == 1:
            return last_logits[0], caches[0]
        return jnp.concatenate(last_logits, 0), _concat_caches(caches)

    def generate(self, prompts: jax.Array, gen_len: int, n_cand: int = 4,
                 max_len: int | None = None) -> GenerationResult:
        """prompts (B, L) int32, B split into the two interleaved batches."""
        assert self.tp is not None, "call load()/init_from_seed() first"
        b, length = prompts.shape
        pol = self.policy or Policy(bs_prefill=max(1, b // 2),
                                    bs_decode=max(1, b // 2),
                                    bs_draft=max(1, b // 2), n_cand=n_cand)
        m = pol.n_cand
        max_len = max_len or (length + gen_len + 3 * (m + 1) + 4)

        half = b // 2
        batches = [prompts[:half], prompts[half:]]
        states = []
        for bt in batches:
            lg, tc = self._prefill_zigzag(self.tp, self.tcfg, bt,
                                          pol.bs_prefill, max_len)
            _, dc = self._prefill_zigzag(self.dp, self.dcfg, bt,
                                         pol.bs_prefill, max_len)
            t0 = jnp.argmax(lg, -1)
            states.append(BatchState(target_cache=tc, draft_cache=dc,
                                     t_next=t0, drafts=None,
                                     draft_pendings=None,
                                     emitted=[(np.asarray(t0)[:, None], 1)]))

        pipe = InterleavedPipeline(self.tp, self.tcfg, self.dp, self.dcfg,
                                   m, self.mesh)
        s0, s1, rounds = pipe.run(states, gen_len)

        out = np.zeros((b, gen_len), np.int32)
        accepts = []
        for bi, st in enumerate((s0, s1)):
            rows = np.zeros((batches[bi].shape[0], 0), np.int32)
            fills = [list() for _ in range(batches[bi].shape[0])]
            for toks, n in st.emitted:
                toks = np.asarray(toks)
                n = np.asarray(n) + np.zeros(toks.shape[0], np.int32)
                for r in range(toks.shape[0]):
                    fills[r].extend(toks[r, :int(n[r])].tolist())
                if toks.shape[1] > 1:
                    accepts.append(n - 1)
            for r, f in enumerate(fills):
                row = (f + [0] * gen_len)[:gen_len]
                out[bi * half + r] = row
            del rows
        return GenerationResult(out, rounds, accepts,
                                pol, self.placement)


def _concat_caches(caches):
    """Concat per-chunk caches over the batch axis (axis 1 for stacked
    layer leaves, axis 0 for 'pos')."""
    layers = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                          *[c["layers"] for c in caches])
    pos = jnp.concatenate([c["pos"] for c in caches], axis=0)
    return {"layers": layers, "pos": pos}
