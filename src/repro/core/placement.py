"""Adaptive Tensor Placement (paper §4.2).

Assigns every tensor of the (target, draft) model pair to a memory tier —
``hbm`` (accelerator), ``host`` (CPU DRAM, the streaming source), ``disk``
— by the paper's priority order:

  1. the *working set* of the streamed target execution: current + next
     layer-group slabs (double-buffered prefetch placeholders);
  2. the draft model and its KV cache (resident in HBM — the paper's
     "low-yield memory repurposing" insight);
  3. extra pinned target tensors, highest-reuse first (embeddings, norms,
     then layer slabs round-robin) while HBM headroom remains;
  4. everything else to host memory; overflow beyond host capacity to disk.

The result is a :class:`PlacementPlan` consumed by
``repro.core.offload.OffloadedModel`` (which realizes tiers with JAX
``memory_kind`` shardings) and by the simulator (which charges each tier's
bandwidth).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.planner import kv_bytes_per_token, layer_ffn_bytes
from repro.sim.hardware import HardwareSpec

TIERS = ("hbm", "host", "disk")


@dataclass
class TensorEntry:
    name: str               # e.g. "target/layer03/ffn", "draft/params"
    bytes: int
    tier: str
    pinned: bool = False    # stays resident (not streamed)
    prefetch_slot: bool = False


@dataclass
class PlacementPlan:
    entries: list
    hbm_used: int
    host_used: int
    disk_used: int
    hbm_capacity: int
    host_capacity: int
    notes: list = field(default_factory=list)

    def tier_of(self, name: str) -> str:
        for e in self.entries:
            if e.name == name:
                return e.tier
        raise KeyError(name)

    def bytes_in(self, tier: str) -> int:
        return sum(e.bytes for e in self.entries if e.tier == tier)

    def streamed_bytes_per_token_step(self) -> int:
        """Bytes that must cross host->HBM per decode step (non-pinned
        target layer slabs)."""
        return sum(e.bytes for e in self.entries
                   if e.name.startswith("target/layer") and not e.pinned
                   and e.tier != "hbm")


def plan_placement(target: ModelConfig, draft: ModelConfig | None,
                   hw: HardwareSpec, *,
                   draft_batch: int = 8, draft_ctx: int = 2048,
                   bytes_per_param: int = 2,
                   reserve_activations: float = 0.10) -> PlacementPlan:
    """Build the placement plan for decode-phase SpecOffload."""
    bp = bytes_per_param
    hbm_cap = int(hw.accel_mem_bytes * (1 - reserve_activations))
    host_cap = int(hw.host_mem_bytes)
    entries: list[TensorEntry] = []
    notes: list[str] = []
    hbm = host = disk = 0

    def place(name, nbytes, want_hbm, pinned=False, prefetch=False):
        nonlocal hbm, host, disk
        nbytes = int(nbytes)
        if want_hbm and hbm + nbytes <= hbm_cap:
            entries.append(TensorEntry(name, nbytes, "hbm", pinned, prefetch))
            hbm += nbytes
            return "hbm"
        if host + nbytes <= host_cap:
            entries.append(TensorEntry(name, nbytes, "host", pinned))
            host += nbytes
            return "host"
        entries.append(TensorEntry(name, nbytes, "disk", pinned))
        disk += nbytes
        return "disk"

    # --- priority 1: streamed working set (double buffer of largest slab)
    slab = layer_ffn_bytes(target, bp)
    place("target/stream_slot0", slab, True, prefetch=True)
    place("target/stream_slot1", slab, True, prefetch=True)

    # --- priority 2: draft model + its KV (the paper's key move)
    if draft is not None:
        t = place("draft/params", draft.param_bytes(bp), True, pinned=True)
        if t != "hbm":
            notes.append("draft did not fit HBM -> speculative decoding "
                         "disabled (falls back to plain offloading)")
        kv = draft_batch * draft_ctx * kv_bytes_per_token(draft, bp)
        place("draft/kv_cache", kv, True, pinned=True)

    # --- priority 3: pin extra target tensors, embeddings first
    emb = target.vocab_size * target.d_model * bp
    place("target/embedding", emb, True, pinned=True)
    attn_bytes = _attn_layer_bytes(target, bp)
    for i in range(target.n_layers):
        place(f"target/layer{i:03d}/attn", attn_bytes, True, pinned=True)
    for i in range(target.n_layers):
        place(f"target/layer{i:03d}/ffn", layer_ffn_bytes(target, bp), True,
              pinned=True)

    # --- target KV cache lives with the host attention compute
    notes.append("target KV cache placed on host (attention computed "
                 "host-side per paper §4.1.2)")

    if disk:
        notes.append(f"{disk/2**30:.1f} GiB overflow to disk "
                     f"(paper §5.5 disk mode)")

    return PlacementPlan(entries, hbm, host, disk, hbm_cap, host_cap, notes)


def _attn_layer_bytes(cfg: ModelConfig, bp: int) -> int:
    hd = cfg.head_dim
    return (cfg.d_model * cfg.n_heads * hd
            + 2 * cfg.d_model * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * cfg.d_model + 2 * cfg.d_model) * bp


def hbm_pinned_fraction(plan: PlacementPlan) -> float:
    """Fraction of target layer params resident in HBM (Fig 2 x-axis)."""
    tot = pin = 0
    for e in plan.entries:
        if e.name.startswith("target/layer"):
            tot += e.bytes
            if e.tier == "hbm":
                pin += e.bytes
    return pin / max(tot, 1)
