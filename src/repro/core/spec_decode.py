"""Speculative decoding: draft-then-verify with batched per-sequence
acceptance, plus the paper's acceptance model (Appendix A.1).

Round protocol (uniform shapes — no per-sequence catch-up feeds)
----------------------------------------------------------------
Invariant: both caches hold positions [0, P); ``t_next`` (B,) is the last
committed token, not yet fed to either model.

1. **Draft** feeds ``n_cand + 1`` tokens one step at a time:
   ``x_0 = t_next``, ``x_i = d_i`` (its own greedy/sampled prediction),
   producing drafts ``d_1..d_m`` (m = n_cand).  The final feed of ``d_m``
   produces no draft but commits it, so a fully-accepted round needs no
   catch-up next round.  Per-step pendings are kept for rollback.
2. **Target** verifies ``[t_next, d_1..d_m]`` in one forward (m+1 positions),
   yielding greedy predictions ``g_0..g_m``.
3. **Accept** ``a = |longest prefix with d_{i+1} == g_i|``; commit ``a+1``
   input tokens on the target, roll the draft back to ``a+1`` kept inputs,
   and emit ``a+1`` new tokens (``d_1..d_a`` plus bonus ``g_a``).  This
   matches the paper: 1..n_cand+1 tokens per round, E[n] per Eq. (12).

Losslessness: with greedy acceptance the emitted stream equals the target
model's own greedy decoding, token for token (tested in
``tests/test_spec_decode.py``).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.models import model as M

# ---------------------------------------------------------------------------
# the paper's acceptance model (Appendix A.1, Eqs. 10-12)


def acceptance_pmf(p: float, n_cand: int) -> jnp.ndarray:
    """P[n_generated = k] for k = 1..n_cand+1 under i.i.d. acceptance p."""
    ks = jnp.arange(1, n_cand + 2)
    pmf = p ** (ks - 1) * (1 - p)
    pmf = pmf.at[-1].set(p ** n_cand)
    return pmf


def expected_generated(p: float, n_cand: int) -> float:
    """E[n_generated] under the paper's acceptance pmf (Eqs. 10-11).

    ERRATUM: the paper's closed form (Eq. 12) is algebraically inconsistent
    with its own pmf — summing k * P[k] over Eqs. (10)-(11) gives the
    truncated-geometric mean ``(1 - p^{n+1}) / (1 - p)`` (Monte-Carlo
    verified in tests/test_spec_decode.py; this also matches Leviathan et
    al. 2023 Eq. 1).  We implement the correct sum.
    """
    if p >= 1.0:
        return float(n_cand + 1)
    return float((1.0 - p ** (n_cand + 1)) / (1.0 - p))


def expected_generated_paper_eq12(p: float, n_cand: int) -> float:
    """The paper's Eq. (12) as printed — kept for the erratum comparison."""
    if p >= 1.0:
        return float(n_cand + 1)
    return float((n_cand * p ** (n_cand + 2)
                  - (n_cand + 1) * p ** (n_cand + 1) + 1) / (1 - p))


def record_acceptance(metrics, n_accept, n_cand: int, live_mask=None,
                      n_draft: int | None = None, mode: str = "chain"):
    """Observe one verified round's per-sequence accepted-draft counts
    into the registry's acceptance histogram (host-side — call with the
    materialized ``RoundOutput.n_accept``, never inside jit).

    ``live_mask`` drops slots holding retired/dummy sequences so the
    histogram reflects real requests only.  The histogram's integer
    buckets 0..n_cand make the paper's acceptance-rate estimate exact:
    ``sum / (count * n_cand)`` is the measured per-round acceptance.
    For trees pass ``n_cand`` = tree depth (the max accepted path length).

    ``n_draft`` is the number of candidate tokens *verified* per sequence
    per round (chain: n_cand; tree: n_nodes - 1).  It feeds the waste
    counters that make chain vs tree efficiency directly comparable:

    * ``spec_tokens_accepted_total{mode=}`` / ``spec_tokens_wasted_total``
      — candidate tokens the target pass kept / threw away;
    * ``spec_verify_rounds_total`` — per-sequence verified rounds (the
      denominator: accepted/rounds + 1 = emitted tokens per target pass);
    * ``spec_accept_depth_total{depth=d}`` — rounds whose accepted path
      reached at least depth d (per-depth acceptance histogram).
    """
    if not metrics.enabled:
        return
    import numpy as _np
    from repro.obs.metrics import acceptance_buckets
    hist = metrics.histogram(
        "spec_accepted_tokens",
        "accepted draft tokens per sequence per verified round",
        buckets=acceptance_buckets(n_cand))
    arr = _np.asarray(n_accept)
    if live_mask is not None:
        arr = arr[_np.asarray(live_mask)]
    for v in arr.tolist():
        hist.observe(float(v))

    n_draft = n_cand if n_draft is None else n_draft
    accepted = metrics.counter(
        "spec_tokens_accepted_total",
        "draft candidate tokens accepted by target verification")
    wasted = metrics.counter(
        "spec_tokens_wasted_total",
        "draft candidate tokens verified by the target but rejected")
    rounds = metrics.counter(
        "spec_verify_rounds_total",
        "per-sequence verified speculation rounds")
    depth_c = metrics.counter(
        "spec_accept_depth_total",
        "rounds whose accepted path reached at least this depth")
    accepted.inc(float(arr.sum()), mode=mode)
    wasted.inc(float((n_draft - arr).sum()), mode=mode)
    rounds.inc(float(arr.size), mode=mode)
    for d in range(1, n_cand + 1):
        depth_c.inc(float((arr >= d).sum()), mode=mode, depth=str(d))


# ---------------------------------------------------------------------------
# acceptance rules


def greedy_acceptance(drafts: jax.Array, target_logits: jax.Array):
    """Greedy (lossless) acceptance.

    drafts (B, m); target_logits (B, m+1, V) for inputs [t_next, d_1..d_m].
    Returns (n_accept (B,) in [0,m], next_token (B,), n_commit (B,) = a+1).
    """
    g = jnp.argmax(target_logits, axis=-1).astype(drafts.dtype)  # (B, m+1)
    m = drafts.shape[1]
    match = drafts == g[:, :m]                                   # d_{i+1}==g_i
    prefix = jnp.cumprod(match.astype(jnp.int32), axis=1)
    a = prefix.sum(axis=1)                                       # (B,)
    next_token = jnp.take_along_axis(g, a[:, None], axis=1)[:, 0]
    return a, next_token, a + 1


def sampled_acceptance(drafts: jax.Array, draft_logits: jax.Array,
                       target_logits: jax.Array, key,
                       temperature: float = 1.0):
    """Leviathan et al. (2023) lossless *sampling* acceptance.

    Accept d_i with prob min(1, p_t(d_i)/p_d(d_i)); on first rejection,
    resample from max(0, p_t - p_d) normalized.  Returns
    (n_accept, next_token, n_commit).
    """
    b, m = drafts.shape
    pt = jax.nn.softmax(target_logits[:, :m] / temperature, axis=-1)
    pd = jax.nn.softmax(draft_logits / temperature, axis=-1)
    di = drafts[..., None]
    pt_d = jnp.take_along_axis(pt, di, axis=-1)[..., 0]
    pd_d = jnp.take_along_axis(pd, di, axis=-1)[..., 0]
    k_acc, k_res, k_bonus = jax.random.split(key, 3)
    u = jax.random.uniform(k_acc, (b, m))
    accept = u < jnp.minimum(1.0, pt_d / jnp.maximum(pd_d, 1e-20))
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    a = prefix.sum(axis=1)

    # residual distribution at the first rejected position
    idx = jnp.minimum(a, m - 1)
    pt_rej = jnp.take_along_axis(pt, idx[:, None, None], axis=1)[:, 0]
    pd_rej = jnp.take_along_axis(pd, idx[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(pt_rej - pd_rej, 0.0)
    residual = residual / jnp.maximum(residual.sum(-1, keepdims=True), 1e-20)
    resampled = jax.random.categorical(k_res, jnp.log(residual + 1e-20))

    # fully-accepted rows sample the bonus position from the target
    bonus_logits = target_logits[:, m] / temperature
    bonus = jax.random.categorical(k_bonus, bonus_logits)
    next_token = jnp.where(a == m, bonus, resampled).astype(drafts.dtype)
    return a, next_token, a + 1


# ---------------------------------------------------------------------------
# draft generation with rollback support


def draft_generate(params, cfg: ModelConfig, cache, t_next: jax.Array,
                   n_cand: int, mesh=None):
    """Generate ``n_cand`` greedy draft tokens, feeding n_cand+1 inputs.

    Returns (drafts (B, m), draft_logits (B, m, V), cache, step_pendings).
    The cache has all n_cand+1 inputs written (pos advanced); roll back with
    :func:`rollback_draft`.
    """
    b = t_next.shape[0]
    tok = t_next[:, None]
    drafts, dlogits, step_pendings = [], [], []
    for i in range(n_cand + 1):
        logits, cache, pend = M.decode(params, cfg, cache, tok, mesh)
        cache = {"layers": cache["layers"], "pos": cache["pos"] + 1}
        step_pendings.append(pend)
        if i < n_cand:
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(tok.dtype)[:, None]
            drafts.append(tok[:, 0])
            dlogits.append(logits[:, 0])
    return (jnp.stack(drafts, axis=1), jnp.stack(dlogits, axis=1), cache,
            step_pendings)


def rollback_draft(cfg: ModelConfig, cache, step_pendings, n_keep):
    """Rewind the draft cache to keep only the first ``n_keep`` (B,) of the
    ``len(step_pendings)`` single-token steps written by draft_generate."""
    m = len(step_pendings)
    nk = jnp.asarray(n_keep, jnp.int32)
    pos0 = cache["pos"] - m
    new_layers = list(cache["layers"])
    for li, kind in enumerate(cfg.layer_pattern):
        if kind == "attn":
            continue  # full cache: stale rows beyond pos are invisible
        if kind == "swa":
            for i, pend in enumerate(step_pendings):
                saved = pend[li]["saved"]
                if not saved:
                    continue
                keep_i = (i < nk).astype(jnp.int32)
                fix = jax.vmap(
                    lambda cc, sv, p=pos0 + i, k=keep_i:
                    _restore_step(cc, sv, p, k, cfg.sliding_window))
                new_layers[li] = fix(new_layers[li],
                                     jax.tree.map(lambda x: x, saved))
        else:  # recurrent: pick the state after n_keep steps
            # stacks: step i holds [state_after_i, state_after_i+1]
            stacks = [p[li]["stack"] for p in step_pendings]
            first = stacks[0]
            posts = [jax.tree.map(lambda s: s[:, :, 1], st) for st in stacks]
            pre = jax.tree.map(lambda s: s[:, :, 0], first)
            seq = jax.tree.map(
                lambda p0, *ps: jnp.concatenate(
                    [p0[:, :, None]] + [x[:, :, None] for x in ps], axis=2),
                pre, *posts)  # (G, B, m+1, ...)
            sel = _select_stacked(cfg, kind)
            new_layers[li] = jax.vmap(lambda st: sel(st, nk))(seq)
    return {"layers": tuple(new_layers), "pos": pos0 + nk}


def _restore_step(cache_kv, saved, pos, keep, window):
    from repro.models.attention import restore_rejected_rows
    return restore_rejected_rows(cache_kv, saved, pos, keep, window)


def _select_stacked(cfg, kind):
    from repro.models import rglru as rglru_lib
    from repro.models import rwkv as rwkv_lib
    return (rglru_lib.select_rglru_state if kind == "rglru"
            else rwkv_lib.select_rwkv_state)


# ---------------------------------------------------------------------------
# one full speculative round (jit-friendly)


def spec_round(target_params, target_cfg: ModelConfig, target_cache,
               draft_params, draft_cfg: ModelConfig, draft_cache,
               t_next: jax.Array, n_cand: int, mesh=None, key=None,
               sample: bool = False):
    """One draft-then-verify round for one batch.

    Returns dict with: tokens (B, m+1) — the m+1 candidate output slots
    (d_1..d_m, bonus); n_emitted (B,) in [1, m+1] — how many of them are
    valid; t_next (B,); updated caches.
    """
    drafts, dlogits, draft_cache, pendings = draft_generate(
        draft_params, draft_cfg, draft_cache, t_next, n_cand, mesh)

    verify_in = jnp.concatenate([t_next[:, None], drafts], axis=1)
    tlogits, target_cache, tpend = M.decode(
        target_params, target_cfg, target_cache, verify_in, mesh)

    if sample:
        a, nxt, n_commit = sampled_acceptance(drafts, dlogits, tlogits, key)
    else:
        a, nxt, n_commit = greedy_acceptance(drafts, tlogits)

    target_cache = M.commit(target_cfg, target_cache, tpend, n_commit,
                            n_cand + 1)
    draft_cache = rollback_draft(draft_cfg, draft_cache, pendings, n_commit)

    # output slots: accepted drafts then the bonus token at slot ``a``
    out = jnp.where(jnp.arange(n_cand)[None, :] < a[:, None], drafts, 0)
    out = jnp.concatenate([out, jnp.zeros_like(a[:, None])], axis=1)
    out = jax.vmap(lambda row, i, t: row.at[i].set(t))(out, a, nxt)
    return {"tokens": out, "n_emitted": a + 1, "t_next": nxt,
            "target_cache": target_cache, "draft_cache": draft_cache,
            "n_accept": a}


# ---------------------------------------------------------------------------
# speculation trees (SpecExec-style): top-k branching per depth, verified
# in one masked target pass
#
# Layout: the tree is flattened breadth-first into a candidate buffer of
# ``n_nodes`` tokens.  Node 0 is the *root* — the last committed token
# ``t_next`` (depth 0, input only).  Level d holds prod(branching[:d])
# nodes: every level-(d-1) node gets the draft's top-``branching[d-1]``
# continuations as children.  Cache rows for the buffer are written at
# *slots* ``[pos, pos + n_nodes)`` in BFS order, while each node's RoPE
# position is the *logical* ``pos + depth`` (siblings are alternatives for
# the same step, so they share a position but occupy distinct slots).
# Attention inside the buffer follows the ancestor-or-self mask; committed
# rows ``< pos`` stay fully visible.  After verification the deepest
# accepted root-to-leaf path is compacted back to contiguous slots
# (:func:`tree_commit_cache`) so the committed prefix never fragments.

#: ancestor sets are packed into int32 bitmasks for the Pallas kernels
MAX_TREE_NODES = 31


@lru_cache(maxsize=None)
def tree_layout(branching: tuple) -> dict:
    """Static BFS layout for a ``branching`` = (k_1, .., k_D) tree.

    Returns numpy arrays (constants under jit): ``n_nodes``, ``depth``
    (n,), ``parent`` (n,) with parent[0] = 0, ``level_sizes`` /
    ``level_offsets`` (D+1,), ``first_child`` (n,) (-1 for leaves),
    ``anc_mask`` (n, n) bool ancestor-or-self, and ``anc_bits`` (n,)
    int32 with bit j set iff node j is an ancestor-or-self of node i.
    """
    branching = tuple(int(k) for k in branching)
    if not branching or any(k < 1 for k in branching):
        raise ValueError(f"branching factors must be >= 1: {branching}")
    level_sizes = [1]
    for k in branching:
        level_sizes.append(level_sizes[-1] * k)
    n = sum(level_sizes)
    if n > MAX_TREE_NODES:
        raise ValueError(f"tree {branching} has {n} nodes; int32 ancestor "
                         f"bitmasks cap the buffer at {MAX_TREE_NODES}")
    offsets = np.concatenate([[0], np.cumsum(level_sizes)[:-1]])
    depth = np.zeros(n, np.int32)
    parent = np.zeros(n, np.int32)
    for d in range(1, len(level_sizes)):
        off, cnt = offsets[d], level_sizes[d]
        depth[off:off + cnt] = d
        parent[off:off + cnt] = offsets[d - 1] + (np.arange(cnt)
                                                  // branching[d - 1])
    first_child = np.full(n, -1, np.int32)
    for d in range(len(branching)):
        off, cnt = offsets[d], level_sizes[d]
        first_child[off:off + cnt] = offsets[d + 1] + (np.arange(cnt)
                                                       * branching[d])
    anc = np.eye(n, dtype=bool)
    for i in range(1, n):
        anc[i] |= anc[parent[i]]
    bits = (anc.astype(np.int64) << np.arange(n)[None, :]).sum(1)
    return {"n_nodes": n, "branching": branching,
            "depth": depth, "parent": parent,
            "level_sizes": np.asarray(level_sizes, np.int32),
            "level_offsets": np.asarray(offsets, np.int32),
            "first_child": first_child,
            "anc_mask": anc, "anc_bits": bits.astype(np.int32)}


def tree_n_nodes(branching) -> int:
    """Buffer size (root + all candidates) of a ``branching`` tree."""
    return int(tree_layout(tuple(branching))["n_nodes"])


def tree_supported(cfg: ModelConfig) -> bool:
    """Tree speculation needs every layer to see the full prefix (the
    ancestor mask subsets full causal attention): all-ATTN decoder-only
    configs.  SWA rings, recurrent state, and cross-attention carry
    order-dependent state that a branched buffer cannot share."""
    return (not cfg.encoder_decoder
            and all(kind == ATTN for kind in cfg.layer_pattern))


def tree_spec(branching: tuple, level: int | None = None) -> dict:
    """The ``spec_tree`` attention descriptor (static numpy constants).

    ``level=None``: verify the whole buffer at once (``prev=0``).
    ``level=d``: the draft's feed of level ``d``'s nodes after ``prev``
    buffer rows are already written.  Keys: ``depths`` (Sq,) node depths,
    ``prev`` rows of the buffer already in cache, ``mask`` (Sq, prev+Sq)
    ancestor-or-self visibility over the buffer written so far, and (full
    buffer only) ``anc_bits`` for the Pallas tree kernels.
    """
    lay = tree_layout(tuple(branching))
    if level is None:
        return {"depths": lay["depth"], "prev": 0, "mask": lay["anc_mask"],
                "anc_bits": lay["anc_bits"]}
    off = int(lay["level_offsets"][level])
    cnt = int(lay["level_sizes"][level])
    return {"depths": lay["depth"][off:off + cnt], "prev": off,
            "mask": lay["anc_mask"][off:off + cnt, :off + cnt]}


# ---------------------------------------------------------------------------
# tree-shaped acceptance model (planner objective; satellite of Eq. 12)


def acceptance_pmf_tree(p: float, branching: tuple) -> jnp.ndarray:
    """P[n_generated = d+1] for d = 0..D on a ``branching`` tree.

    Per-level coverage under i.i.d. acceptance p: the accepted node at
    depth d-1 has k_d children, each independently acceptable with prob
    p, so the path extends with ``q_d = 1 - (1-p)^{k_d}`` (any child
    matches).  The emitted count is path length + 1 (bonus token).
    """
    branching = tuple(branching)
    qs = [1.0 - (1.0 - p) ** k for k in branching]
    pmf, run = [], 1.0
    for q in qs:
        pmf.append(run * (1.0 - q))
        run *= q
    pmf.append(run)
    return jnp.asarray(pmf)


def expected_generated_tree(p: float, branching: tuple) -> float:
    """E[n_generated] for a tree: ``1 + sum_d prod_{j<=d} q_j`` — the tree
    analogue of :func:`expected_generated` (chain = all k_j = 1)."""
    if p >= 1.0:
        return float(len(tuple(branching)) + 1)
    e, run = 1.0, 1.0
    for k in tuple(branching):
        run *= 1.0 - (1.0 - p) ** k
        e += run
    return float(e)


# ---------------------------------------------------------------------------
# tree acceptance rules


def tree_greedy_acceptance(tokens: jax.Array, target_logits: jax.Array,
                           branching: tuple):
    """Greedy (lossless) acceptance over a verified tree buffer.

    ``tokens`` (B, N) is the BFS buffer (root = committed ``t_next`` at
    column 0); ``target_logits`` (B, N, V) are the target's logits at
    every node.  A node is *accepted* iff its token equals the target's
    greedy prediction at its parent AND its parent is accepted — so the
    accepted set is exactly the target's own greedy path through the
    tree (top-k children are distinct, hence at most one child per level
    matches the unique argmax).

    Returns ``(n_accept (B,), next_token (B,), out_tokens (B, D+1),
    path_idx (B, D+1))`` where ``path_idx[:, d]`` is the buffer index of
    the accepted depth-d node (0 = root beyond the path) for
    :func:`tree_commit_cache`.
    """
    lay = tree_layout(tuple(branching))
    depth_cap = len(lay["level_sizes"]) - 1
    b = tokens.shape[0]
    g = jnp.argmax(target_logits, axis=-1).astype(tokens.dtype)   # (B, N)

    acc_levels = [jnp.ones((b, 1), bool)]                         # root
    for d in range(1, depth_cap + 1):
        off = int(lay["level_offsets"][d])
        cnt = int(lay["level_sizes"][d])
        par = lay["parent"][off:off + cnt]
        match = tokens[:, off:off + cnt] == g[:, par]
        par_local = par - int(lay["level_offsets"][d - 1])
        acc_levels.append(match & acc_levels[d - 1][:, par_local])

    n_accept = sum(lvl.any(axis=1).astype(jnp.int32)
                   for lvl in acc_levels[1:])                     # (B,)
    path_cols = [jnp.zeros((b,), jnp.int32)]
    out_cols = []
    for d in range(1, depth_cap + 1):
        off = int(lay["level_offsets"][d])
        cnt = int(lay["level_sizes"][d])
        lvl = acc_levels[d].astype(jnp.int32)                     # <=1 hot
        idx = jnp.arange(off, off + cnt, dtype=jnp.int32)
        path_cols.append((lvl * idx[None, :]).sum(axis=1))
        out_cols.append((lvl.astype(tokens.dtype)
                         * tokens[:, off:off + cnt]).sum(axis=1))
    path_idx = jnp.stack(path_cols, axis=1)                       # (B, D+1)
    best = jnp.take_along_axis(path_idx, n_accept[:, None], axis=1)[:, 0]
    nxt = jnp.take_along_axis(g, best[:, None], axis=1)[:, 0]
    out = jnp.stack(out_cols + [jnp.zeros((b,), tokens.dtype)], axis=1)
    out = jax.vmap(lambda row, i, t: row.at[i].set(t))(out, n_accept, nxt)
    return n_accept, nxt, out, path_idx


def tree_sampled_acceptance(tokens: jax.Array, draft_logits: jax.Array,
                            target_logits: jax.Array, branching: tuple,
                            key, temperature: float = 1.0):
    """SpecInfer-style multi-candidate rejection sampling down the tree.

    At the current accepted node, try its k children in draft-rank order:
    accept child c with prob ``min(1, res(c) / p_d(c))`` where ``res``
    starts as the target distribution; on rejection subtract the draft
    proposal mass and renormalize both (sampling-without-replacement
    correction), and if every child is rejected emit a token from the
    residual.  Greedy mode is the losslessness-tested path; this sampled
    walk is distribution-sanity-tested in tests/test_tree_spec.py.

    Same return signature as :func:`tree_greedy_acceptance`.
    """
    lay = tree_layout(tuple(branching))
    branching = lay["branching"]
    b, _, v = target_logits.shape
    pt_all = jax.nn.softmax(target_logits / temperature, axis=-1)
    pd_all = jax.nn.softmax(draft_logits / temperature, axis=-1)
    keys = jax.random.split(key, sum(branching) + len(branching) + 1)
    ki = 0

    cur = jnp.zeros((b,), jnp.int32)          # deepest accepted node
    alive = jnp.ones((b,), bool)              # path still extending
    n_accept = jnp.zeros((b,), jnp.int32)
    nxt = jnp.zeros((b,), tokens.dtype)
    path_cols = [cur]
    out_cols = []
    fc_arr = jnp.asarray(lay["first_child"])
    for d, k_d in enumerate(branching):
        fc = fc_arr[cur]                                          # (B,)
        res = jnp.take_along_axis(pt_all, cur[:, None, None], 1)[:, 0]
        pdm = jnp.take_along_axis(pd_all, cur[:, None, None], 1)[:, 0]
        accepted = jnp.zeros((b,), bool)
        child_tok = jnp.zeros((b,), tokens.dtype)
        child_idx = cur
        for j in range(k_d):
            cidx = fc + j
            ctok = jnp.take_along_axis(tokens, cidx[:, None], 1)[:, 0]
            ci = ctok[:, None].astype(jnp.int32)
            p_res = jnp.take_along_axis(res, ci, 1)[:, 0]
            p_d = jnp.take_along_axis(pdm, ci, 1)[:, 0]
            u = jax.random.uniform(keys[ki], (b,))
            ki += 1
            acc_j = (alive & ~accepted
                     & (u < jnp.minimum(1.0, p_res
                                        / jnp.maximum(p_d, 1e-20))))
            child_tok = jnp.where(acc_j, ctok, child_tok)
            child_idx = jnp.where(acc_j, cidx, child_idx)
            accepted |= acc_j
            rej = alive & ~accepted
            res_new = jnp.maximum(res - pdm, 0.0)
            res_new = res_new / jnp.maximum(
                res_new.sum(-1, keepdims=True), 1e-20)
            res = jnp.where(rej[:, None], res_new, res)
            pdm_new = pdm * (1.0 - jax.nn.one_hot(ctok, v, dtype=pdm.dtype))
            pdm_new = pdm_new / jnp.maximum(
                pdm_new.sum(-1, keepdims=True), 1e-20)
            pdm = jnp.where(rej[:, None], pdm_new, pdm)
        failed = alive & ~accepted
        bonus = jax.random.categorical(keys[ki], jnp.log(res + 1e-20))
        ki += 1
        nxt = jnp.where(failed, bonus.astype(tokens.dtype), nxt)
        n_accept += accepted.astype(jnp.int32)
        alive &= accepted
        out_cols.append(jnp.where(accepted, child_tok, 0))
        cur = jnp.where(accepted, child_idx, cur)
        path_cols.append(jnp.where(accepted, child_idx, 0))
    pt_deep = jnp.take_along_axis(pt_all, cur[:, None, None], 1)[:, 0]
    bonus = jax.random.categorical(keys[ki], jnp.log(pt_deep + 1e-20))
    nxt = jnp.where(alive, bonus.astype(tokens.dtype), nxt)
    out = jnp.stack(out_cols + [jnp.zeros((b,), tokens.dtype)], axis=1)
    out = jax.vmap(lambda row, i, t: row.at[i].set(t))(out, n_accept, nxt)
    return n_accept, nxt, out, jnp.stack(path_cols, axis=1)


# ---------------------------------------------------------------------------
# tree draft generation + accepted-path commit


def draft_tree_generate(params, cfg: ModelConfig, cache, t_next: jax.Array,
                        branching: tuple, mesh=None,
                        collect_logits: bool = False):
    """Expand the draft's top-k speculation tree level by level.

    Feeds the root (``t_next``) then each level's nodes in one masked
    decode step per depth; every level-(d-1) node contributes its
    top-``branching[d-1]`` continuations.  All ``n_nodes`` buffer rows
    end up written to the cache (slots ``[pos, pos + n_nodes)``), so a
    fully-accepted round needs no catch-up feed — mirroring the chain's
    n_cand+1 protocol.  Returns ``(tok_buf (B, N), draft_logits
    (B, N, V) | None, cache)`` with ``pos`` advanced by ``n_nodes``.
    """
    lay = tree_layout(tuple(branching))
    branching = lay["branching"]
    b = t_next.shape[0]
    feed = t_next[:, None].astype(jnp.int32)
    toks, dlogits = [feed], []
    for d in range(len(branching) + 1):
        spec = tree_spec(branching, level=d)
        logits, cache, _ = M.decode(params, cfg, cache, feed, mesh,
                                    spec_tree=spec)
        cache = dict(cache, pos=cache["pos"] + feed.shape[1])
        if collect_logits:
            dlogits.append(logits)
        if d < len(branching):
            _, topk = jax.lax.top_k(logits, branching[d])  # (B, w, k)
            feed = topk.reshape(b, -1).astype(jnp.int32)
            toks.append(feed)
    tok_buf = jnp.concatenate(toks, axis=1)
    logits_buf = (jnp.concatenate(dlogits, axis=1) if collect_logits
                  else None)
    return tok_buf, logits_buf, cache


def tree_commit_cache(cfg: ModelConfig, cache, path_idx: jax.Array,
                      n_keep, branching: tuple, pos_offset: int = 0):
    """Commit a verified tree's accepted root path by *compaction*: the
    accepted buffer rows (root + path) are gathered from their scattered
    BFS slots and scattered back contiguously at the frontier, then
    ``pos`` advances past the kept rows.  Rows beyond the new ``pos``
    are stale-but-invisible (the standing decode invariant) and get
    overwritten by the next round's buffer.

    ``path_idx`` (B, D+1) comes from the acceptance rule; ``n_keep``
    (B,) is the accepted path length ``a`` (``a + 1`` rows kept).
    ``pos_offset`` is how far ``cache['pos']`` already advanced past the
    buffer start (0 for the target, whose decode does not move ``pos``;
    ``n_nodes`` for the draft after :func:`draft_tree_generate`).
    Supports contiguous and paged (block-table) ATTN caches.
    """
    from repro.models.attention import paged_row_indices
    dplus = path_idx.shape[1]
    nk = jnp.asarray(n_keep, jnp.int32)
    base = cache["pos"] - pos_offset                       # (B,)
    src = base[:, None] + path_idx                         # (B, D+1)
    dst = base[:, None] + jnp.arange(dplus, dtype=jnp.int32)[None, :]
    paged = "block_tables" in cache

    def fix_pool(p, srows, drows):
        nb, bs = p.shape[1], p.shape[2]
        flat = p.reshape((p.shape[0], nb * bs) + p.shape[3:])
        rows = flat[:, srows.reshape(-1)]
        flat = flat.at[:, drows.reshape(-1)].set(rows)
        return flat.reshape(p.shape)

    def fix_buf(buf):
        def one(rowbuf, s_b, d_b):     # (S, ...) per (group, batch)
            rows = jnp.take(rowbuf, s_b, axis=0, mode="clip")
            return rowbuf.at[d_b].set(rows, mode="drop")
        return jax.vmap(lambda bg: jax.vmap(one)(bg, src, dst))(buf)

    new_layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        if kind != ATTN:
            raise ValueError("tree_commit_cache requires an all-attention "
                             f"layer pattern (layer {i} is {kind!r})")
        leaf = cache["layers"][i]
        if paged:
            bs_blk = leaf["k"].shape[2]
            srows = paged_row_indices(cache["block_tables"], src, bs_blk)
            drows = paged_row_indices(cache["block_tables"], dst, bs_blk)
            new_layers.append({kk: fix_pool(vv, srows, drows)
                               for kk, vv in leaf.items()})
        else:
            new_layers.append({kk: fix_buf(vv) for kk, vv in leaf.items()})
    out = dict(cache, layers=tuple(new_layers), pos=base + nk + 1)
    return out


# ---------------------------------------------------------------------------
# one full tree-speculation round (jit-friendly; mirrors spec_round)


def spec_round_tree(target_params, target_cfg: ModelConfig, target_cache,
                    draft_params, draft_cfg: ModelConfig, draft_cache,
                    t_next: jax.Array, branching: tuple, mesh=None,
                    key=None, sample: bool = False):
    """One draft-tree-then-verify round for one batch.

    Same contract as :func:`spec_round` with ``tokens`` (B, D+1): the
    accepted path's tokens then the bonus token at slot ``a``.
    """
    branching = tuple(branching)
    n_nodes = tree_n_nodes(branching)
    tok_buf, dlogits, draft_cache = draft_tree_generate(
        draft_params, draft_cfg, draft_cache, t_next, branching, mesh,
        collect_logits=sample)

    tlogits, target_cache, _ = M.decode(
        target_params, target_cfg, target_cache, tok_buf, mesh,
        spec_tree=tree_spec(branching))

    if sample:
        a, nxt, out, path_idx = tree_sampled_acceptance(
            tok_buf, dlogits, tlogits, branching, key)
    else:
        a, nxt, out, path_idx = tree_greedy_acceptance(tok_buf, tlogits,
                                                       branching)

    target_cache = tree_commit_cache(target_cfg, target_cache, path_idx,
                                     a, branching)
    draft_cache = tree_commit_cache(draft_cfg, draft_cache, path_idx,
                                    a, branching, pos_offset=n_nodes)
    return {"tokens": out, "n_emitted": a + 1, "t_next": nxt,
            "target_cache": target_cache, "draft_cache": draft_cache,
            "n_accept": a}
