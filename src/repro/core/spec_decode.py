"""Speculative decoding: draft-then-verify with batched per-sequence
acceptance, plus the paper's acceptance model (Appendix A.1).

Round protocol (uniform shapes — no per-sequence catch-up feeds)
----------------------------------------------------------------
Invariant: both caches hold positions [0, P); ``t_next`` (B,) is the last
committed token, not yet fed to either model.

1. **Draft** feeds ``n_cand + 1`` tokens one step at a time:
   ``x_0 = t_next``, ``x_i = d_i`` (its own greedy/sampled prediction),
   producing drafts ``d_1..d_m`` (m = n_cand).  The final feed of ``d_m``
   produces no draft but commits it, so a fully-accepted round needs no
   catch-up next round.  Per-step pendings are kept for rollback.
2. **Target** verifies ``[t_next, d_1..d_m]`` in one forward (m+1 positions),
   yielding greedy predictions ``g_0..g_m``.
3. **Accept** ``a = |longest prefix with d_{i+1} == g_i|``; commit ``a+1``
   input tokens on the target, roll the draft back to ``a+1`` kept inputs,
   and emit ``a+1`` new tokens (``d_1..d_a`` plus bonus ``g_a``).  This
   matches the paper: 1..n_cand+1 tokens per round, E[n] per Eq. (12).

Losslessness: with greedy acceptance the emitted stream equals the target
model's own greedy decoding, token for token (tested in
``tests/test_spec_decode.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

# ---------------------------------------------------------------------------
# the paper's acceptance model (Appendix A.1, Eqs. 10-12)


def acceptance_pmf(p: float, n_cand: int) -> jnp.ndarray:
    """P[n_generated = k] for k = 1..n_cand+1 under i.i.d. acceptance p."""
    ks = jnp.arange(1, n_cand + 2)
    pmf = p ** (ks - 1) * (1 - p)
    pmf = pmf.at[-1].set(p ** n_cand)
    return pmf


def expected_generated(p: float, n_cand: int) -> float:
    """E[n_generated] under the paper's acceptance pmf (Eqs. 10-11).

    ERRATUM: the paper's closed form (Eq. 12) is algebraically inconsistent
    with its own pmf — summing k * P[k] over Eqs. (10)-(11) gives the
    truncated-geometric mean ``(1 - p^{n+1}) / (1 - p)`` (Monte-Carlo
    verified in tests/test_spec_decode.py; this also matches Leviathan et
    al. 2023 Eq. 1).  We implement the correct sum.
    """
    if p >= 1.0:
        return float(n_cand + 1)
    return float((1.0 - p ** (n_cand + 1)) / (1.0 - p))


def expected_generated_paper_eq12(p: float, n_cand: int) -> float:
    """The paper's Eq. (12) as printed — kept for the erratum comparison."""
    if p >= 1.0:
        return float(n_cand + 1)
    return float((n_cand * p ** (n_cand + 2)
                  - (n_cand + 1) * p ** (n_cand + 1) + 1) / (1 - p))


def record_acceptance(metrics, n_accept, n_cand: int, live_mask=None):
    """Observe one verified round's per-sequence accepted-draft counts
    into the registry's acceptance histogram (host-side — call with the
    materialized ``RoundOutput.n_accept``, never inside jit).

    ``live_mask`` drops slots holding retired/dummy sequences so the
    histogram reflects real requests only.  The histogram's integer
    buckets 0..n_cand make the paper's acceptance-rate estimate exact:
    ``sum / (count * n_cand)`` is the measured per-round acceptance.
    """
    if not metrics.enabled:
        return
    import numpy as _np
    from repro.obs.metrics import acceptance_buckets
    hist = metrics.histogram(
        "spec_accepted_tokens",
        "accepted draft tokens per sequence per verified round",
        buckets=acceptance_buckets(n_cand))
    arr = _np.asarray(n_accept)
    if live_mask is not None:
        arr = arr[_np.asarray(live_mask)]
    for v in arr.tolist():
        hist.observe(float(v))


# ---------------------------------------------------------------------------
# acceptance rules


def greedy_acceptance(drafts: jax.Array, target_logits: jax.Array):
    """Greedy (lossless) acceptance.

    drafts (B, m); target_logits (B, m+1, V) for inputs [t_next, d_1..d_m].
    Returns (n_accept (B,) in [0,m], next_token (B,), n_commit (B,) = a+1).
    """
    g = jnp.argmax(target_logits, axis=-1).astype(drafts.dtype)  # (B, m+1)
    m = drafts.shape[1]
    match = drafts == g[:, :m]                                   # d_{i+1}==g_i
    prefix = jnp.cumprod(match.astype(jnp.int32), axis=1)
    a = prefix.sum(axis=1)                                       # (B,)
    next_token = jnp.take_along_axis(g, a[:, None], axis=1)[:, 0]
    return a, next_token, a + 1


def sampled_acceptance(drafts: jax.Array, draft_logits: jax.Array,
                       target_logits: jax.Array, key,
                       temperature: float = 1.0):
    """Leviathan et al. (2023) lossless *sampling* acceptance.

    Accept d_i with prob min(1, p_t(d_i)/p_d(d_i)); on first rejection,
    resample from max(0, p_t - p_d) normalized.  Returns
    (n_accept, next_token, n_commit).
    """
    b, m = drafts.shape
    pt = jax.nn.softmax(target_logits[:, :m] / temperature, axis=-1)
    pd = jax.nn.softmax(draft_logits / temperature, axis=-1)
    di = drafts[..., None]
    pt_d = jnp.take_along_axis(pt, di, axis=-1)[..., 0]
    pd_d = jnp.take_along_axis(pd, di, axis=-1)[..., 0]
    k_acc, k_res, k_bonus = jax.random.split(key, 3)
    u = jax.random.uniform(k_acc, (b, m))
    accept = u < jnp.minimum(1.0, pt_d / jnp.maximum(pd_d, 1e-20))
    prefix = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    a = prefix.sum(axis=1)

    # residual distribution at the first rejected position
    idx = jnp.minimum(a, m - 1)
    pt_rej = jnp.take_along_axis(pt, idx[:, None, None], axis=1)[:, 0]
    pd_rej = jnp.take_along_axis(pd, idx[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(pt_rej - pd_rej, 0.0)
    residual = residual / jnp.maximum(residual.sum(-1, keepdims=True), 1e-20)
    resampled = jax.random.categorical(k_res, jnp.log(residual + 1e-20))

    # fully-accepted rows sample the bonus position from the target
    bonus_logits = target_logits[:, m] / temperature
    bonus = jax.random.categorical(k_bonus, bonus_logits)
    next_token = jnp.where(a == m, bonus, resampled).astype(drafts.dtype)
    return a, next_token, a + 1


# ---------------------------------------------------------------------------
# draft generation with rollback support


def draft_generate(params, cfg: ModelConfig, cache, t_next: jax.Array,
                   n_cand: int, mesh=None):
    """Generate ``n_cand`` greedy draft tokens, feeding n_cand+1 inputs.

    Returns (drafts (B, m), draft_logits (B, m, V), cache, step_pendings).
    The cache has all n_cand+1 inputs written (pos advanced); roll back with
    :func:`rollback_draft`.
    """
    b = t_next.shape[0]
    tok = t_next[:, None]
    drafts, dlogits, step_pendings = [], [], []
    for i in range(n_cand + 1):
        logits, cache, pend = M.decode(params, cfg, cache, tok, mesh)
        cache = {"layers": cache["layers"], "pos": cache["pos"] + 1}
        step_pendings.append(pend)
        if i < n_cand:
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(tok.dtype)[:, None]
            drafts.append(tok[:, 0])
            dlogits.append(logits[:, 0])
    return (jnp.stack(drafts, axis=1), jnp.stack(dlogits, axis=1), cache,
            step_pendings)


def rollback_draft(cfg: ModelConfig, cache, step_pendings, n_keep):
    """Rewind the draft cache to keep only the first ``n_keep`` (B,) of the
    ``len(step_pendings)`` single-token steps written by draft_generate."""
    m = len(step_pendings)
    nk = jnp.asarray(n_keep, jnp.int32)
    pos0 = cache["pos"] - m
    new_layers = list(cache["layers"])
    for li, kind in enumerate(cfg.layer_pattern):
        if kind == "attn":
            continue  # full cache: stale rows beyond pos are invisible
        if kind == "swa":
            for i, pend in enumerate(step_pendings):
                saved = pend[li]["saved"]
                if not saved:
                    continue
                keep_i = (i < nk).astype(jnp.int32)
                fix = jax.vmap(
                    lambda cc, sv, p=pos0 + i, k=keep_i:
                    _restore_step(cc, sv, p, k, cfg.sliding_window))
                new_layers[li] = fix(new_layers[li],
                                     jax.tree.map(lambda x: x, saved))
        else:  # recurrent: pick the state after n_keep steps
            # stacks: step i holds [state_after_i, state_after_i+1]
            stacks = [p[li]["stack"] for p in step_pendings]
            first = stacks[0]
            posts = [jax.tree.map(lambda s: s[:, :, 1], st) for st in stacks]
            pre = jax.tree.map(lambda s: s[:, :, 0], first)
            seq = jax.tree.map(
                lambda p0, *ps: jnp.concatenate(
                    [p0[:, :, None]] + [x[:, :, None] for x in ps], axis=2),
                pre, *posts)  # (G, B, m+1, ...)
            sel = _select_stacked(cfg, kind)
            new_layers[li] = jax.vmap(lambda st: sel(st, nk))(seq)
    return {"layers": tuple(new_layers), "pos": pos0 + nk}


def _restore_step(cache_kv, saved, pos, keep, window):
    from repro.models.attention import restore_rejected_rows
    return restore_rejected_rows(cache_kv, saved, pos, keep, window)


def _select_stacked(cfg, kind):
    from repro.models import rglru as rglru_lib
    from repro.models import rwkv as rwkv_lib
    return (rglru_lib.select_rglru_state if kind == "rglru"
            else rwkv_lib.select_rwkv_state)


# ---------------------------------------------------------------------------
# one full speculative round (jit-friendly)


def spec_round(target_params, target_cfg: ModelConfig, target_cache,
               draft_params, draft_cfg: ModelConfig, draft_cache,
               t_next: jax.Array, n_cand: int, mesh=None, key=None,
               sample: bool = False):
    """One draft-then-verify round for one batch.

    Returns dict with: tokens (B, m+1) — the m+1 candidate output slots
    (d_1..d_m, bonus); n_emitted (B,) in [1, m+1] — how many of them are
    valid; t_next (B,); updated caches.
    """
    drafts, dlogits, draft_cache, pendings = draft_generate(
        draft_params, draft_cfg, draft_cache, t_next, n_cand, mesh)

    verify_in = jnp.concatenate([t_next[:, None], drafts], axis=1)
    tlogits, target_cache, tpend = M.decode(
        target_params, target_cfg, target_cache, verify_in, mesh)

    if sample:
        a, nxt, n_commit = sampled_acceptance(drafts, dlogits, tlogits, key)
    else:
        a, nxt, n_commit = greedy_acceptance(drafts, tlogits)

    target_cache = M.commit(target_cfg, target_cache, tpend, n_commit,
                            n_cand + 1)
    draft_cache = rollback_draft(draft_cfg, draft_cache, pendings, n_commit)

    # output slots: accepted drafts then the bonus token at slot ``a``
    out = jnp.where(jnp.arange(n_cand)[None, :] < a[:, None], drafts, 0)
    out = jnp.concatenate([out, jnp.zeros_like(a[:, None])], axis=1)
    out = jax.vmap(lambda row, i, t: row.at[i].set(t))(out, a, nxt)
    return {"tokens": out, "n_emitted": a + 1, "t_next": nxt,
            "target_cache": target_cache, "draft_cache": draft_cache,
            "n_accept": a}
