"""Whisper-style encoder stack (arXiv:2212.04356).

Per the assignment brief, the modality frontend (mel-spectrogram + conv
feature extractor) is a *stub*: ``input_specs`` provides precomputed frame
embeddings of shape (B, encoder_len, d_model).  This module implements the
transformer encoder that consumes them: sinusoidal positions, bidirectional
attention, GELU MLPs, LayerNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.attention import attention_chunked, init_attention
from repro.models.layers import (apply_mlp, apply_norm, init_mlp, init_norm,
                                 mlp_specs, norm_specs, sinusoidal_positions)


def init_encoder(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    n = cfg.n_encoder_layers

    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": init_norm(cfg.d_model, cfg.norm, dt),
            "attn": init_attention(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, dt),
            "ln2": init_norm(cfg.d_model, cfg.norm, dt),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dt),
        }

    keys = jax.random.split(key, n)
    return {"layers": jax.vmap(one)(keys),
            "final_norm": init_norm(cfg.d_model, cfg.norm, dt)}


def encoder_specs(cfg: ModelConfig) -> dict:
    lift = lambda s: P(None, *s)
    one = {
        "ln1": norm_specs(cfg.norm),
        "attn": attn_lib.attention_specs(),
        "ln2": norm_specs(cfg.norm),
        "mlp": mlp_specs(cfg.activation),
    }
    return {"layers": jax.tree.map(lift, one,
                                   is_leaf=lambda s: isinstance(s, P)),
            "final_norm": norm_specs(cfg.norm)}


def apply_encoder(params: dict, cfg: ModelConfig,
                  frames: jax.Array) -> jax.Array:
    """frames (B, T, D) stub embeddings -> encoder states (B, T, D)."""
    b, t, d = frames.shape
    x = frames + sinusoidal_positions(t, d).astype(frames.dtype)
    scale = cfg.head_dim ** -0.5
    positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, layer):
        h = apply_norm(layer["ln1"], x, cfg.norm)
        q = (h @ layer["attn"]["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ layer["attn"]["wk"]).reshape(b, t, cfg.n_kv_heads,
                                              cfg.head_dim)
        v = (h @ layer["attn"]["wv"]).reshape(b, t, cfg.n_kv_heads,
                                              cfg.head_dim)
        out = attention_chunked(q, k, v, positions, positions, scale,
                                causal=False)
        x = x + out @ layer["attn"]["wo"]
        x = x + apply_mlp(layer["mlp"], apply_norm(layer["ln2"], x, cfg.norm),
                          cfg.activation)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return apply_norm(params["final_norm"], x, cfg.norm)
