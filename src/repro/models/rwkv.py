"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay.

Time-mix (per head of size ``hd``; r, k, w are (hd,), v is (hd,))::

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)        # u = per-channel bonus
    S_t = diag(w_t) S_{t-1} + k_t v_t^T              # w_t = data-dep. decay

with ``w_t = exp(-exp(w0 + tanh(x_w @ A) @ B))`` (low-rank data dependence).
Token-shift interpolation ``lerp(x_t, x_{t-1}, mu_*)`` feeds each projection.

Channel-mix: ``out = sigmoid(r) * ( relu(k)^2 @ Wv )`` with token shift.

State per layer: ``{"S": (B,H,hd,hd) f32, "ts_a": (B,D), "ts_c": (B,D)}``
(the last input for the time-mix / channel-mix token shifts).  Multi-token
decode returns per-step state stacks for speculative rollback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, seq_axis, shard_hint

_LORA = 64


def _pick_segment(s: int, target: int = 64) -> int:
    """Largest divisor of s not exceeding target (remat segment length)."""
    seg = min(target, s)
    while s % seg:
        seg -= 1
    return seg


def init_rwkv_tmix(key, d_model: int, head_size: int, dtype) -> dict:
    ks = jax.random.split(key, 10)
    d = d_model
    decay = jnp.linspace(-6.0, -2.0, d).astype(jnp.float32)
    return {
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": dense_init(ks[0], d, d, dtype), "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype), "w_g": dense_init(ks[3], d, d, dtype),
        "w_o": dense_init(ks[4], d, d, dtype),
        "w0": decay,                                   # base log-log decay
        "w_lora_a": dense_init(ks[5], d, _LORA, jnp.float32),
        "w_lora_b": (jax.random.normal(ks[6], (_LORA, d)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[7], (d,)) * 0.1).astype(jnp.float32),
        "ln_x": jnp.ones((d,), jnp.float32),           # per-head group norm
    }


def tmix_specs() -> dict:
    return {
        "mu_r": P(None), "mu_k": P(None), "mu_v": P(None), "mu_g": P(None),
        "mu_w": P(None),
        "w_r": P("data", "model"), "w_k": P("data", "model"),
        "w_v": P("data", "model"), "w_g": P("data", "model"),
        "w_o": P("model", "data"),
        "w0": P("model"), "w_lora_a": P("data", None), "w_lora_b": P(None, "model"),
        "u": P("model"), "ln_x": P("model"),
    }


def init_rwkv_cmix(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "w_k": dense_init(ks[0], d_model, d_ff, dtype),
        "w_v": dense_init(ks[1], d_ff, d_model, dtype),
        "w_r": dense_init(ks[2], d_model, d_model, dtype),
    }


def cmix_specs() -> dict:
    return {"mu_k": P(None), "mu_r": P(None),
            "w_k": P("data", "model"), "w_v": P("model", "data"),
            "w_r": P("data", "model")}


def init_rwkv_state(batch: int, d_model: int, head_size: int, dtype) -> dict:
    h = d_model // head_size
    return {"S": jnp.zeros((batch, h, head_size, head_size), jnp.float32),
            "ts_a": jnp.zeros((batch, d_model), dtype),
            "ts_c": jnp.zeros((batch, d_model), dtype)}


def rwkv_state_specs(batch_spec) -> dict:
    return {"S": P(batch_spec, "model", None, None),
            "ts_a": P(batch_spec, None), "ts_c": P(batch_spec, None)}


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x_{t-1} stream: prev for t=0, x shifted right otherwise."""
    return jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def apply_rwkv_tmix(params: dict, x: jax.Array, state_S: jax.Array,
                    ts_prev: jax.Array, head_size: int):
    """Time mix over x (B,S,D). Returns (out, S_stack (B,S,H,hd,hd),
    new ts (B,D))."""
    b, s, d = x.shape
    h = d // head_size
    xp = _token_shift(x, ts_prev)
    # keep (B,S,D) projections sharded channel-on-model inside the block
    dsh = (lambda z: shard_hint(z, "data", None, "model")) \
        if seq_axis() == "model" else (lambda z: z)
    r = dsh(_lerp(x, xp, params["mu_r"]) @ params["w_r"])
    k = dsh(_lerp(x, xp, params["mu_k"]) @ params["w_k"])
    v = dsh(_lerp(x, xp, params["mu_v"]) @ params["w_v"])
    g = dsh(jax.nn.silu(_lerp(x, xp, params["mu_g"]) @ params["w_g"]))
    xw = _lerp(x, xp, params["mu_w"]).astype(jnp.float32)
    w_log = params["w0"] + jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    w = dsh(jnp.exp(-jnp.exp(w_log)))                        # (B,S,D) in (0,1)

    def heads(z):
        return z.reshape(b, s, h, head_size).astype(jnp.float32)

    r_, k_, v_, w_ = heads(r), heads(k), heads(v), heads(w)
    u = params["u"].reshape(h, head_size)
    if seq_axis() == "model":
        state_S = shard_hint(state_S, "data", "model", None, None)
    want_stack = s <= 16  # decode/verify path keeps per-step states

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                             # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]           # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, ((y, S) if want_stack else y)

    swap = lambda z: jnp.swapaxes(z, 0, 1)                   # time-major
    xs = (swap(r_), swap(k_), swap(v_), swap(w_))
    if want_stack:
        S_last, (yT, ST) = jax.lax.scan(step, state_S, xs)
        S_stack = jnp.swapaxes(ST, 0, 1)                     # (B,S,H,hd,hd)
    else:
        # Training/prefill: the (hd x hd) state stack would be O(S*D*hd)
        # bytes; scan in remat segments so backward only stores the state
        # at segment boundaries and recomputes inside (classic BPTT
        # checkpointing).
        seg = _pick_segment(s)
        n_seg = s // seg

        def seg_step(S, seg_xs):
            return jax.lax.scan(step, S, seg_xs)

        seg_step = jax.checkpoint(seg_step)
        xs_seg = jax.tree.map(
            lambda z: z.reshape(n_seg, seg, *z.shape[1:]), xs)
        S_last, yT = jax.lax.scan(
            lambda S, sx: seg_step(S, sx), state_S, xs_seg)
        yT = yT.reshape(s, b, h, head_size)
        S_stack = S_last[:, None]                            # (B,1,H,hd,hd)
    y = jnp.swapaxes(yT, 0, 1)                               # (B,S,H,hd)

    # per-head RMS "group norm"
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6)
    y = (y.reshape(b, s, d) * params["ln_x"]).astype(x.dtype)
    out = (y * g) @ params["w_o"]
    return out, S_stack, x[:, -1]


def apply_rwkv_cmix(params: dict, x: jax.Array, ts_prev: jax.Array):
    xp = _token_shift(x, ts_prev)
    k = _lerp(x, xp, params["mu_k"]) @ params["w_k"]
    kv = jnp.square(jax.nn.relu(k)) @ params["w_v"]
    r = jax.nn.sigmoid(_lerp(x, xp, params["mu_r"]) @ params["w_r"])
    return r * kv, x[:, -1]


def apply_rwkv_block(tmix: dict, cmix: dict, ln1, ln2, x: jax.Array,
                     state: dict, head_size: int, norm_fn):
    """Full RWKV layer (pre-norm residual twice).

    Returns (out, new_state, state_stack|None).  ``state_stack`` (decode
    only, S<=16) holds per-step S / token-shift values for rollback.
    """
    b, s, _ = x.shape
    a_in = norm_fn(ln1, x)
    a_out, S_stack, ts_a = apply_rwkv_tmix(tmix, a_in, state["S"],
                                           state["ts_a"], head_size)
    x = x + a_out
    c_in = norm_fn(ln2, x)
    c_out, ts_c = apply_rwkv_cmix(cmix, c_in, state["ts_c"])
    x = x + c_out
    new_state = {"S": S_stack[:, -1], "ts_a": ts_a, "ts_c": ts_c}
    stack = None
    if s <= 16:
        # token-shift stacks are the (normed) inputs at each step; index 0
        # holds the pre-step state so commit(n=0) is expressible
        stack = {
            "S": jnp.concatenate([state["S"][:, None], S_stack], axis=1),
            "ts_a": jnp.concatenate(
                [state["ts_a"][:, None].astype(a_in.dtype), a_in], axis=1),
            "ts_c": jnp.concatenate(
                [state["ts_c"][:, None].astype(c_in.dtype), c_in], axis=1),
        }
    return x, new_state, stack


def select_rwkv_state(stack: dict, index: jax.Array) -> dict:
    b = index.shape[0]
    bi = jnp.arange(b)
    return {"S": stack["S"][bi, index],
            "ts_a": stack["ts_a"][bi, index],
            "ts_c": stack["ts_c"][bi, index]}
