"""Pure-JAX model substrate: layers, attention, MoE, recurrent blocks.

Parameters are plain pytrees (nested dicts of jnp arrays); every module
exposes ``init_*`` (PRNG -> params), ``apply``-style pure functions, and a
``*_specs`` twin returning a same-structure pytree of
``jax.sharding.PartitionSpec`` for the production mesh.
"""
