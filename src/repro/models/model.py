"""Top-level model API: init / specs / train / prefill / decode / commit.

Every architecture in ``repro.configs`` flows through these six functions;
the SpecOffload engine (``repro.core``) and the launchers call nothing
deeper.  All functions are pure and jit-friendly; ``mesh`` is a static
argument (None on single-device CPU runs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import encdec
from repro.models.layers import embed_tokens, shard_hint
from repro.models.transformer import (cache_specs, commit_cache,
                                      decoder_param_specs, forward_decoder,
                                      init_cache, init_decoder_params,
                                      logits_from_hidden)

__all__ = [
    "init_params", "param_specs", "forward_train", "loss_fn", "prefill",
    "decode", "commit", "init_cache", "cache_specs", "shard_hint",
]




# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    params = init_decoder_params(k1, cfg)
    if cfg.encoder_decoder:
        params["encoder"] = encdec.init_encoder(k2, cfg)
    return params


def param_specs(cfg: ModelConfig, model_size: int = 16) -> dict:
    specs = decoder_param_specs(cfg, model_size)
    if cfg.encoder_decoder:
        specs["encoder"] = encdec.encoder_specs(cfg)
    return specs


def _embed(params, cfg, tokens):
    x = embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    return shard_hint(x, "data", None, None)


def _encoder_out(params, cfg, batch):
    if not cfg.encoder_decoder:
        return None
    return encdec.apply_encoder(params["encoder"], cfg,
                                batch["encoder_frames"])


# ---------------------------------------------------------------------------
# training


def forward_train(params: dict, cfg: ModelConfig, batch: dict,
                  mesh=None) -> jax.Array:
    """batch: {'tokens': (B,S) int32, ['encoder_frames': (B,T,D)]}.

    Returns next-token logits (B, S, V) in f32.
    """
    x = _embed(params, cfg, batch["tokens"])
    enc_out = _encoder_out(params, cfg, batch)
    h, _, _ = forward_decoder(params, cfg, x, phase="train", mesh=mesh,
                              enc_out=enc_out)
    return logits_from_hidden(params, cfg, h)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict,
            mesh=None, logits_chunk: int = 256) -> jax.Array:
    """Causal LM cross-entropy (next-token); ignores the last position.

    The (B, S, V) logits are never materialized: the unembed + softmax-xent
    runs in rematted chunks over the sequence (a 256k-vocab model at S=4k
    would otherwise need gigabytes of f32 logits per chip).
    """
    from repro.models.layers import apply_norm, unembed
    x = _embed(params, cfg, batch["tokens"])
    enc_out = _encoder_out(params, cfg, batch)
    h, _, _ = forward_decoder(params, cfg, x, phase="train", mesh=mesh,
                              enc_out=enc_out)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    h = h[:, :-1]
    targets = batch["tokens"][:, 1:].astype(jnp.int32)

    b, s, d = h.shape
    c = min(logits_chunk, s)
    while s % c:
        c -= 1
    n = s // c
    hc = h.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n, c).transpose(1, 0, 2)

    def chunk_nll(total, inp):
        h_i, t_i = inp
        logits = unembed(params["embed"], h_i)            # (b, c, V) f32
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, t_i[..., None], axis=-1)
        return total + nll.sum(), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk_nll),
                            jnp.zeros((), jnp.float32), (hc, tc))
    return total / (b * s)


# ---------------------------------------------------------------------------
# serving


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict,
            mesh=None, encoder_frames: jax.Array | None = None):
    """Process the prompt (B, L); fill the cache.

    Returns (last-position logits (B, V), cache with pos=L).
    """
    b, length = tokens.shape
    x = _embed(params, cfg, tokens)
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = encdec.apply_encoder(params["encoder"], cfg, encoder_frames)
    h, new_cache, _ = forward_decoder(params, cfg, x, phase="prefill",
                                      cache=cache, mesh=mesh, enc_out=enc_out)
    logits = logits_from_hidden(params, cfg, h[:, -1:])[:, 0]
    new_cache["pos"] = jnp.full((b,), length, jnp.int32)
    return logits, new_cache


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jax.Array,
           mesh=None, spec_tree: dict | None = None):
    """Decode/verify ``m`` new tokens (B, m) at positions cache['pos'].

    Writes the cache eagerly and returns (logits (B,m,V), cache, pendings);
    call :func:`commit` with the number of accepted tokens to finalize.
    For plain autoregressive decoding use m=1 then ``commit(..., n=1)``.
    ``spec_tree`` marks ``tokens`` as speculation-tree nodes (depth-based
    positions + ancestor masking; see
    :func:`repro.core.spec_decode.tree_spec`).
    """
    x = _embed(params, cfg, tokens)
    h, new_cache, pendings = forward_decoder(params, cfg, x, phase="decode",
                                             cache=cache, mesh=mesh,
                                             spec_tree=spec_tree)
    return logits_from_hidden(params, cfg, h), new_cache, pendings


def commit(cfg: ModelConfig, cache: dict, pendings, n_commit,
           sq: int) -> dict:
    """Accept the first ``n_commit`` (B,) of the ``sq`` decoded tokens."""
    return commit_cache(cfg, cache, pendings, n_commit, sq)


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, mesh=None):
    """One committed autoregressive step (B, 1) -> (logits (B,V), cache)."""
    logits, cache, pendings = decode(params, cfg, cache, tokens, mesh)
    b = tokens.shape[0]
    cache = commit(cfg, cache, pendings, jnp.ones((b,), jnp.int32), 1)
    return logits[:, 0], cache
