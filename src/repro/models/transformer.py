"""Decoder stack assembly: layer-group scan, cache plumbing, phase dispatch.

The model is organized as ``n_groups`` repetitions of the config's
``layer_pattern`` (e.g. ``('rglru','rglru','swa')`` for RecurrentGemma,
``('swa',)*5 + ('attn',)`` for Gemma-3).  Parameters and caches are *stacked*
over the group axis and the forward pass is a single ``lax.scan`` over
groups, which keeps HLO size flat for 126-layer models and lets remat wrap
one group at a time.

Phases
------
* ``train`` / ``prefill`` — full-sequence; prefill additionally (re)fills the
  cache.  Positions are uniform (scalar offset 0).
* ``decode`` — Sq in [1, 16] new tokens per sequence at per-sequence
  positions ``cache['pos']`` (B,).  Writes are performed eagerly; the
  returned ``pending`` pytree carries what `commit` needs to *undo* writes
  for rejected speculative tokens (ring-buffer rows, recurrent state stacks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, RGLRU, RWKV, SWA, ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv as rwkv_lib
from repro.models.attention import (apply_attention, init_attention,
                                    init_kv_cache, init_paged_kv_pool,
                                    paged_row_indices, quantize_rows,
                                    restore_rejected_rows)
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 embedding_specs, init_embedding, init_mlp,
                                 init_norm, mlp_specs, norm_specs, unembed)

MAX_DECODE_TOKENS = 16


def _sqrt_factor(n: int, threshold: int = 8) -> int:
    """Outer superblock count for sqrt-remat (1 = disabled)."""
    if n < threshold:
        return 1
    best = 1
    import math
    root = math.isqrt(n)
    for k in range(root, 0, -1):
        if n % k == 0:
            best = k
            break
    return best if best > 1 else 1


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _norm_fn(cfg: ModelConfig):
    return lambda p, x: apply_norm(p, x, cfg.norm)


# ---------------------------------------------------------------------------
# per-layer init / specs / apply


def init_layer(key, cfg: ModelConfig, kind: str,
               use_moe: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p = {"ln1": init_norm(cfg.d_model, cfg.norm, dt),
         "ln2": init_norm(cfg.d_model, cfg.norm, dt)}
    if kind in (ATTN, SWA):
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, dt)
        if cfg.encoder_decoder:
            kx = jax.random.split(ks[2], 2)
            p["xattn"] = init_attention(kx[0], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, dt)
            p["ln_x"] = init_norm(cfg.d_model, cfg.norm, dt)
        if use_moe:
            p["ffn"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.n_experts, cfg.activation, dt)
        else:
            p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                cfg.activation, dt)
    elif kind == RGLRU:
        p["rec"] = rglru_lib.init_rglru(ks[0], cfg.d_model, cfg.rnn_width,
                                        cfg.conv_width, dt)
        p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    elif kind == RWKV:
        p["tmix"] = rwkv_lib.init_rwkv_tmix(ks[0], cfg.d_model,
                                            cfg.rwkv_head_size, dt)
        p["cmix"] = rwkv_lib.init_rwkv_cmix(ks[1], cfg.d_model, cfg.d_ff, dt)
    else:
        raise ValueError(kind)
    return p


def layer_specs(cfg: ModelConfig, kind: str, model_size: int,
                use_moe: bool = False) -> dict:
    p = {"ln1": norm_specs(cfg.norm), "ln2": norm_specs(cfg.norm)}
    if kind in (ATTN, SWA):
        p["attn"] = attn_lib.attention_specs()
        if cfg.encoder_decoder:
            p["xattn"] = attn_lib.attention_specs()
            p["ln_x"] = norm_specs(cfg.norm)
        if use_moe:
            p["ffn"] = moe_lib.moe_storage_specs(cfg.activation,
                                                 cfg.n_experts, model_size)
        else:
            p["ffn"] = mlp_specs(cfg.activation)
    elif kind == RGLRU:
        p["rec"] = rglru_lib.rglru_specs()
        p["ffn"] = mlp_specs(cfg.activation)
    elif kind == RWKV:
        p["tmix"] = rwkv_lib.tmix_specs()
        p["cmix"] = rwkv_lib.cmix_specs()
    return p


def apply_layer(params: dict, cfg: ModelConfig, kind: str, x: jax.Array,
                cache: dict | None, pos, phase: str, mesh=None,
                enc_out: jax.Array | None = None, use_moe: bool = False,
                block_tables: jax.Array | None = None,
                spec_tree: dict | None = None):
    """Returns (x, new_cache, pending)."""
    nf = _norm_fn(cfg)
    pending = {}
    # Megatron-style sequence parallelism: the residual stream between
    # layers is sequence-sharded on 'model' (cheap to store); gather it here
    # so weight matmuls see replicated-S activations and SPMD gathers only
    # the small FSDP weight shards — NOT the full (D, F) matrix (which it
    # would do, in f32, if S stayed 'model'-sharded through the matmul).
    from repro.models.layers import fsdp_axes, gather_seq, shard_hint
    x = gather_seq(x)
    if phase == "decode":
        # weight-stationary decode (§Perf hillclimb #2): contraction-shard
        # the tiny token block to match the weights' at-rest FSDP sharding
        # (('pod','data') on the multi-pod mesh) so the qkv projections
        # psum instead of all-gathering their weights
        ax = fsdp_axes()
        if ax is not None:
            x = shard_hint(x, None, None, ax)
    if kind in (ATTN, SWA):
        window = cfg.sliding_window if kind == SWA else None
        self_cache = None
        if cache is not None:
            self_cache = {kk: vv for kk, vv in cache.items()
                          if kk in ("k", "v", "k_scale", "v_scale")}
        out, new_kv, saved = apply_attention(
            params["attn"], nf(params["ln1"], x),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            use_rope=cfg.use_rope, window=window,
            cache=self_cache, pos=pos, phase=phase,
            block_tables=block_tables if kind == ATTN else None,
            spec_tree=spec_tree)
        x = x + out
        if phase == "decode":
            # Weight-stationary decode (§Perf hillclimb #2): the token
            # block is tiny (B x m x D), so shard its *feature* dim to
            # match the weights' contraction sharding — the FFN matmuls
            # become local-partial + psum, moving ~MBs of activations per
            # layer instead of all-gathering the 2D-sharded weights (GBs
            # per step on a 405B model).
            from repro.models.layers import fsdp_axes, shard_hint
            ax = fsdp_axes()
            if ax is not None:
                x = shard_hint(x, None, None, ax)
        if "xattn" in params:  # encoder-decoder cross attention
            if phase in ("prefill", "train") or cache is None or \
                    "ck" not in cache:
                cross_kv = attn_lib.precompute_cross_kv(
                    params["xattn"], enc_out, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.head_dim)
            else:
                cross_kv = {"ck": cache["ck"], "cv": cache["cv"]}
            x = x + attn_lib.apply_cross_attention(
                params["xattn"], nf(params["ln_x"], x), cross_kv,
                n_heads=cfg.n_heads, head_dim=cfg.head_dim)
            if new_kv is not None:
                new_kv = dict(new_kv, **cross_kv)
        h = nf(params["ln2"], x)
        if use_moe:
            # decode steps are few-token: dropless dispatch is free there and
            # keeps speculative verification exact (no batch-dependent drops)
            cf = (float("inf") if (cfg.moe_dropless or phase == "decode")
                  else cfg.capacity_factor)
            f = moe_lib.apply_moe(params["ffn"], h, n_experts=cfg.n_experts,
                                  top_k=cfg.top_k, activation=cfg.activation,
                                  mesh=mesh, capacity_factor=cf)
        else:
            f = apply_mlp(params["ffn"], h, cfg.activation)
        x = x + f
        if phase == "decode":
            pending = {"saved": saved}
        return x, new_kv, pending

    if kind == RGLRU:
        out, new_state, stack = rglru_lib.apply_rglru_block(
            params["rec"], nf(params["ln1"], x),
            cache if cache is not None
            else rglru_lib.init_rglru_state(x.shape[0], cfg.rnn_width,
                                            cfg.conv_width, x.dtype))
        x = x + out
        x = x + apply_mlp(params["ffn"], nf(params["ln2"], x), cfg.activation)
        if phase == "decode":
            pending = {"stack": stack}
        return x, new_state, pending

    if kind == RWKV:
        state = (cache if cache is not None else
                 rwkv_lib.init_rwkv_state(x.shape[0], cfg.d_model,
                                          cfg.rwkv_head_size, x.dtype))
        x, new_state, stack = rwkv_lib.apply_rwkv_block(
            params["tmix"], params["cmix"], params["ln1"], params["ln2"],
            x, state, cfg.rwkv_head_size, nf)
        if phase == "decode":
            pending = {"stack": stack}
        return x, new_state, pending

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int,
                     max_len: int) -> dict | None:
    dt = _dtype(cfg)
    if kind == ATTN:
        c = init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim, dt,
                          quant=cfg.kv_cache_dtype == "int8")
        if cfg.encoder_decoder:
            c["ck"] = jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads,
                                 cfg.head_dim), dt)
            c["cv"] = jnp.zeros_like(c["ck"])
        return c
    if kind == SWA:
        return init_kv_cache(batch, min(cfg.sliding_window, max_len),
                             cfg.n_kv_heads, cfg.head_dim, dt)
    if kind == RGLRU:
        return rglru_lib.init_rglru_state(batch, cfg.rnn_width,
                                          cfg.conv_width, dt)
    if kind == RWKV:
        return rwkv_lib.init_rwkv_state(batch, cfg.d_model,
                                        cfg.rwkv_head_size, dt)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked-over-groups cache: leaves get a leading (n_groups,) axis."""
    layers = []
    for kind in cfg.layer_pattern:
        one = init_layer_cache(cfg, kind, batch, max_len)
        layers.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), one))
    return {"layers": tuple(layers),
            "pos": jnp.zeros((batch,), jnp.int32)}


# ---------------------------------------------------------------------------
# paged serving cache (block-table KV for full-attention layers)


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, max_blocks_per_seq: int,
                     kv_quant: bool | None = None) -> dict:
    """Serving cache with *paged* full-attention KV.

    ATTN layers share one ``(num_blocks, block_size, ...)`` pool per layer
    group; each sequence addresses it through its ``block_tables`` row
    (``max_blocks_per_seq`` entries, 0 = the reserved scratch block).
    Sliding-window / recurrent layers keep their per-slot state — rings
    are window-bounded, so paging them buys nothing.  ``kv_quant``
    overrides ``cfg.kv_cache_dtype`` for the pool (int8 cold blocks on an
    otherwise-fp model config).
    """
    if cfg.encoder_decoder:
        raise ValueError("paged KV serving supports decoder-only models")
    quant = (cfg.kv_cache_dtype == "int8") if kv_quant is None else kv_quant
    dt = _dtype(cfg)
    layers = []
    for kind in cfg.layer_pattern:
        if kind == ATTN:
            one = init_paged_kv_pool(num_blocks, block_size, cfg.n_kv_heads,
                                     cfg.head_dim, dt, quant=quant)
        else:
            one = init_layer_cache(cfg, kind, batch,
                                   max_blocks_per_seq * block_size)
        layers.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), one))
    return {"layers": tuple(layers),
            "pos": jnp.zeros((batch,), jnp.int32),
            "block_tables": jnp.zeros((batch, max_blocks_per_seq),
                                      jnp.int32)}


def admit_sequence_paged(cfg: ModelConfig, cache: dict, prefill: dict,
                         slot, table_row, length, n_shared) -> dict:
    """Graft a (B=1) contiguous prefill cache into batch slot ``slot`` of a
    paged serving cache.

    ATTN layers scatter prefill rows [``n_shared * block_size``, ``length``)
    into the blocks named by ``table_row`` (rows covered by prefix-shared
    blocks are skipped — their content is already in the pool); other layer
    kinds splice per-slot state exactly like the contiguous path.  Rows are
    quantized on insert when the pool is int8 and the prefill cache is not.
    ``slot``/``table_row``/``length``/``n_shared`` may be traced, so one
    compile covers every admission.
    """
    bt = cache["block_tables"]
    mbs = bt.shape[1]
    row = jnp.asarray(table_row, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    start = jnp.asarray(n_shared, jnp.int32) * _paged_block_size(cache, cfg)
    new_layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        big, small = cache["layers"][i], prefill["layers"][i]
        if kind == ATTN:
            new_layers.append(_paged_insert_layer(big, small, row, start,
                                                  length))
        else:
            new_layers.append(jax.tree.map(
                lambda b_, s_: jax.lax.dynamic_update_index_in_dim(
                    b_, s_[:, 0].astype(b_.dtype), slot, 1), big, small))
    pos = jax.lax.dynamic_update_index_in_dim(
        cache["pos"], length.astype(cache["pos"].dtype), slot, 0)
    bt = jax.lax.dynamic_update_index_in_dim(bt, row, slot, 0)
    return {"layers": tuple(new_layers), "pos": pos, "block_tables": bt}


def _paged_block_size(cache: dict, cfg: ModelConfig) -> int:
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == ATTN:
            return cache["layers"][i]["k"].shape[2]
    raise ValueError("paged cache has no full-attention layer")


def _paged_insert_layer(pool: dict, prefill: dict, table_row, start,
                        length) -> dict:
    """Scatter one layer group's prefill rows into its block pool.

    ``pool`` leaves are (G, NB, BS, H, d); ``prefill`` leaves (G, 1, L, H,
    d).  Rows outside [start, length) are redirected to the scratch block
    (block 0), which the engine never grants.
    """
    g, nb, bs = pool["k"].shape[:3]
    l = prefill["k"].shape[2]
    i = jnp.arange(l, dtype=jnp.int32)
    valid = (i >= start) & (i < length)
    idx = paged_row_indices(jnp.asarray(table_row)[None, :], i[None, :],
                            bs)[0]
    idx = jnp.where(valid, idx, i % bs)          # scratch block rows
    quant_pool = "k_scale" in pool
    quant_src = "k_scale" in prefill

    def scat(p, rows):
        flat = p.reshape((nb * bs,) + p.shape[2:])
        flat = flat.at[idx].set(rows.astype(p.dtype))
        return flat.reshape(p.shape)

    out = {}
    if quant_pool and not quant_src:
        def one_group(pk, pv, psk, psv, sk, sv):
            kq, ks = quantize_rows(sk)
            vq, vs = quantize_rows(sv)
            return (scat(pk, kq), scat(pv, vq), scat(psk, ks),
                    scat(psv, vs))
        k, v, ks_, vs_ = jax.vmap(one_group)(
            pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
            prefill["k"][:, 0], prefill["v"][:, 0])
        out = {"k": k, "v": v, "k_scale": ks_, "v_scale": vs_}
    else:
        for key in pool:
            out[key] = jax.vmap(scat)(pool[key], prefill[key][:, 0])
    return out


def release_slot_paged(cache: dict, slot) -> dict:
    """Neutralize a retired slot: point its table row at the scratch block
    and rewind ``pos`` so the still-running fused step can never write
    into blocks that were freed (and possibly re-granted)."""
    bt = cache["block_tables"]
    bt = jax.lax.dynamic_update_index_in_dim(
        bt, jnp.zeros((bt.shape[1],), bt.dtype), slot, 0)
    pos = jax.lax.dynamic_update_index_in_dim(
        cache["pos"], jnp.zeros((), cache["pos"].dtype), slot, 0)
    return dict(cache, pos=pos, block_tables=bt)


def cache_specs(cfg: ModelConfig, batch_spec, seq_spec) -> dict:
    """PartitionSpecs matching :func:`init_cache` (leading group axis)."""
    layers = []
    for kind in cfg.layer_pattern:
        if kind in (ATTN, SWA):
            one = attn_lib.kv_cache_specs(
                batch_spec, seq_spec,
                quant=(kind == ATTN and cfg.kv_cache_dtype == "int8"))
            if cfg.encoder_decoder and kind == ATTN:
                one["ck"] = P(batch_spec, None, None, None)
                one["cv"] = P(batch_spec, None, None, None)
        elif kind == RGLRU:
            one = rglru_lib.rglru_state_specs(batch_spec)
        else:
            one = rwkv_lib.rwkv_state_specs(batch_spec)
        layers.append(jax.tree.map(
            lambda s: P(None, *s), one,
            is_leaf=lambda s: isinstance(s, P)))
    return {"layers": tuple(layers), "pos": P(None)}


# ---------------------------------------------------------------------------
# stacked init / specs for the whole decoder


def init_decoder_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.layer_pattern) + 2)
    layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        gkeys = jax.random.split(keys[i], cfg.n_groups)
        moe_i = bool(cfg.is_moe and cfg.moe_pattern[i])
        layers.append(jax.vmap(
            lambda k, m=moe_i: init_layer(k, cfg, kind, m))(gkeys))
    dt = _dtype(cfg)
    return {
        "embed": init_embedding(keys[-2], cfg.vocab_size, cfg.d_model, dt,
                                cfg.tie_embeddings),
        "layers": tuple(layers),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dt),
    }


def decoder_param_specs(cfg: ModelConfig, model_size: int = 16) -> dict:
    layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        moe_i = bool(cfg.is_moe and cfg.moe_pattern[i])
        one = layer_specs(cfg, kind, model_size, moe_i)
        layers.append(jax.tree.map(
            lambda s: P(None, *s), one,
            is_leaf=lambda s: isinstance(s, P)))
    return {
        "embed": embedding_specs(cfg.tie_embeddings, cfg.vocab_size,
                                 cfg.d_model, model_size),
        "layers": tuple(layers),
        "final_norm": norm_specs(cfg.norm),
    }


# ---------------------------------------------------------------------------
# forward


def forward_decoder(params: dict, cfg: ModelConfig, x: jax.Array, *,
                    phase: str, cache: dict | None = None, mesh=None,
                    enc_out: jax.Array | None = None,
                    spec_tree: dict | None = None):
    """Run the stacked decoder over embedded inputs x (B, S, D).

    Returns (hidden, new_cache, pendings).  ``enc_out`` is the encoder
    output for encoder-decoder configs (closed over by every layer).
    ``spec_tree`` (decode only) marks x as a speculation-tree buffer —
    static numpy constants closed over by every layer; see
    :func:`repro.models.attention.apply_attention`.
    """
    pos = cache["pos"] if (cache is not None and phase == "decode") else 0
    layer_caches = cache["layers"] if cache is not None else None
    # paged serving cache: block tables are read-only within a step, so
    # they ride the scan closure (not the carry) — one (B, MBS) int32 array
    # shared by every full-attention layer group
    block_tables = (cache.get("block_tables")
                    if (cache is not None and phase == "decode") else None)

    train = phase == "train"

    def apply_group(x, gparams, gcache):
        new_caches, pendings = [], []
        for i, kind in enumerate(cfg.layer_pattern):
            moe_i = bool(cfg.is_moe and cfg.moe_pattern[i])
            x, nc, pend = apply_layer(gparams[i], cfg, kind, x, gcache[i],
                                      pos, phase, mesh, enc_out=enc_out,
                                      use_moe=moe_i,
                                      block_tables=block_tables,
                                      spec_tree=spec_tree)
            new_caches.append(nc)
            pendings.append(pend)
        return x, tuple(new_caches), tuple(pendings)

    if layer_caches is not None:
        # Serving: thread the (stacked) cache through the scan *carry* and
        # update it in place per group.  Passing it as scan xs/ys instead
        # would materialize two extra full-cache copies (the sliced inputs
        # and the re-stacked outputs) — tens of GiB for 32k-context caches.
        def body(carry, group_in):
            x, cache_layers = carry
            j, gparams = group_in
            gcache = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, j, 0,
                                                       keepdims=False),
                cache_layers)
            x, new_caches, pendings = apply_group(x, gparams, gcache)
            cache_layers = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(
                    a, n.astype(a.dtype), j, 0),
                cache_layers, new_caches)
            return (x, cache_layers), tuple(pendings)

        idx = jnp.arange(cfg.n_groups, dtype=jnp.int32)
        (x, new_layer_caches), pendings = jax.lax.scan(
            body, (x, layer_caches), (idx, params["layers"]))
        new_cache = {"layers": new_layer_caches, "pos": cache["pos"]}
        if block_tables is not None:
            new_cache["block_tables"] = block_tables
        return x, new_cache, pendings

    # Training / cache-less forward: plain scan over stacked params with
    # (sqrt-)remat; for large models the per-group residual carry is
    # offloaded to host memory (the paper's offload tier applied to
    # training — ZeRO-R-style activation offload).
    def body(x, gparams):
        if train and cfg.offload_carries:
            from jax.ad_checkpoint import checkpoint_name
            x = checkpoint_name(x, "group_carry")
        x, _, _ = apply_group(x, gparams, (None,) * len(cfg.layer_pattern))
        if train:
            # keep the inter-group carry sequence-sharded so the residuals
            # reverse-mode AD stores per group are 1/seq_axis per chip
            from repro.models.layers import seq_hint
            x = seq_hint(x, 1, 1)
        return x, None

    if cfg.remat and train:
        if cfg.offload_carries:
            policy = jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["group_carry"],
                offload_src="device", offload_dst="pinned_host")
            body = jax.checkpoint(body, policy=policy)
        else:
            body = jax.checkpoint(body)

    n_outer = 1 if (cfg.offload_carries and cfg.remat and train) else (
        _sqrt_factor(cfg.n_groups) if (cfg.remat and train) else 1)
    if n_outer > 1:
        # sqrt-remat: scan superblocks of groups with an outer checkpoint,
        # so only n_outer + n_inner carries are live instead of n_groups
        # (126-layer models would otherwise store one (B,S,D) residual per
        # layer group).
        n_inner = cfg.n_groups // n_outer
        xs2 = jax.tree.map(
            lambda a: a.reshape(n_outer, n_inner, *a.shape[1:]),
            params["layers"])

        @jax.checkpoint
        def outer_body(x, sxs):
            return jax.lax.scan(body, x, sxs)

        x, _ = jax.lax.scan(outer_body, x, xs2)
    else:
        x, _ = jax.lax.scan(body, x, params["layers"])
    return x, None, ()


def logits_from_hidden(params: dict, cfg: ModelConfig,
                       x: jax.Array) -> jax.Array:
    h = apply_norm(params["final_norm"], x, cfg.norm)
    return unembed(params["embed"], h)


# ---------------------------------------------------------------------------
# commit for speculative decoding


def commit_cache(cfg: ModelConfig, cache: dict, pendings, n_commit,
                 sq: int) -> dict:
    """Finalize a verify step: keep ``n_commit`` (B,) of the ``sq`` written
    tokens, undo the rest, and advance ``pos``.

    ``pendings`` is the scan-stacked pending pytree from
    :func:`forward_decoder` (leaves have a leading (n_groups,) axis).
    """
    nc = jnp.asarray(n_commit, jnp.int32)
    pos = cache["pos"]
    new_layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        c = cache["layers"][i]
        pend = pendings[i]
        if kind == ATTN:
            new_layers.append(c)  # over-written rows are invisible
        elif kind == SWA:
            saved = pend["saved"]
            if not saved:   # cache larger than window -> behaves like full
                new_layers.append(c)
            else:
                fix = jax.vmap(
                    lambda cc, sv: restore_rejected_rows(
                        cc, sv, pos, nc, cfg.sliding_window))
                new_layers.append(fix(c, saved))
        else:  # recurrent: stack index n = state after n committed tokens
            stack = pend["stack"]
            sel = rglru_lib.select_rglru_state if kind == RGLRU \
                else rwkv_lib.select_rwkv_state
            idx = jnp.clip(nc, 0, sq)
            new_layers.append(jax.vmap(lambda st: sel(st, idx))(stack))
    out = {"layers": tuple(new_layers), "pos": pos + nc}
    if "block_tables" in cache:
        out["block_tables"] = cache["block_tables"]
    return out
