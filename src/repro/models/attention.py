"""Attention: GQA with RoPE, full/sliding-window variants, KV caches.

Two execution paths share one masking scheme:

* ``attention_chunked`` — flash-style online-softmax ``lax.scan`` over KV
  chunks; used for train/prefill where Sq is large.  Never materializes the
  (Sq, Skv) score matrix; per-step footprint is (Sq, kv_chunk).
* ``attention_direct`` — plain masked softmax; used for decode/verify where
  Sq is 1..(n_cand+1).  Works with a sequence-sharded KV cache: GSPMD
  partitions the softmax reduction (partial max/sum + all-reduce).

KV caches are fixed-size buffers.  Full-attention layers use ``S_max`` slots
indexed by logical position; sliding-window (SWA) layers use a ``window``-slot
ring buffer written at ``pos % window``.  Masks are derived *analytically*
from the scalar ``pos`` — slot ``j`` of a ring holds logical position
``p_j = (L-1) - ((L-1-j) mod W)`` for cache length ``L`` — so no slot-position
bookkeeping array is needed.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import (apply_rope, dense_init, rope_table,
                                 seq_axis, seq_hint, shard_hint)

NEG_INF = -1e30


def _use_paged_kernel() -> bool:
    """Route paged decode through the Pallas block-table kernel on TPU;
    the CPU CI path uses the gather + masked-softmax reference instead
    (interpret-mode Pallas would dominate test wall time)."""
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# params


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def attention_specs() -> dict:
    return {"wq": P("data", "model"), "wk": P("data", "model"),
            "wv": P("data", "model"), "wo": P("model", "data")}


# ---------------------------------------------------------------------------
# masking helpers


def ring_slot_positions(n_slots: int, length, window: int) -> jax.Array:
    """Logical position held by each ring-buffer slot given cache length.

    ``length`` is the number of tokens written so far — a scalar or a (B,)
    per-sequence array.  Slots not yet written get a negative position
    (always masked).  Output (n_slots,) or (B, n_slots).
    """
    j = jnp.arange(n_slots, dtype=jnp.int32)
    last = jnp.asarray(length, jnp.int32) - 1
    if last.ndim:
        last = last[:, None]
    return last - jnp.mod(last - j, jnp.asarray(window, jnp.int32))


def attention_mask(q_positions: jax.Array, kv_positions: jax.Array,
                   window: int | None, causal: bool = True) -> jax.Array:
    """Additive mask in f32: 0 allowed / NEG_INF disallowed.

    ``q_positions`` is (Sq,) or (B, Sq); ``kv_positions`` is (Skv,) or
    (B, Skv).  The result broadcasts to (..., Sq, Skv).
    """
    qp = q_positions[..., :, None]
    kp = kv_positions[..., None, :]
    ok = kp >= 0
    if causal:
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & (kp > qp - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _tree_decode_mask(base: jax.Array, tree_mask, n_kv: int) -> jax.Array:
    """Additive (B, Sq, n_kv) mask for one tree-speculation decode step.

    ``base`` (B,) is where the speculation buffer starts in the cache;
    ``tree_mask`` (Sq, W) is the static ancestor-or-self visibility of
    the Sq fed nodes over the W buffer rows written so far.  Committed
    rows (< base) stay fully visible, buffer rows [base, base+W) follow
    the tree mask, and stale rows past the buffer are hidden.
    """
    tm = jnp.asarray(np.asarray(tree_mask))
    w = tm.shape[1]
    kv_idx = jnp.arange(n_kv, dtype=jnp.int32)[None, :]
    col = kv_idx - base[:, None]                            # (B, n_kv)
    allowed = jnp.transpose(tm[:, jnp.clip(col, 0, w - 1)], (1, 0, 2))
    ok = (col < 0)[:, None, :] | (((col >= 0) & (col < w))[:, None, :]
                                  & allowed)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# attention cores (GQA-aware)


def _split_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, Hq, d) -> (B, S, n_kv, g, d)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def attention_direct(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, scale: float) -> jax.Array:
    """Masked softmax attention; q (B,Sq,Hq,d), k/v (B,Skv,Hkv,d).

    ``mask`` is (Sq, Skv) or per-sequence (B, Sq, Skv).
    """
    n_kv = k.shape[2]
    qg = _split_gqa(q, n_kv)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    s = s + mask[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    b, sq = q.shape[:2]
    return out.reshape(b, sq, -1).astype(q.dtype)


def _chunk_kv(k, v, kv_positions, kv_chunk):
    b, skv, n_kv, d = k.shape
    kv_chunk = min(kv_chunk, skv)
    n_chunks = math.ceil(skv / kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kc = k.reshape(b, n_chunks, kv_chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, n_kv, d).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, kv_chunk)
    return kc, vc, pc, pad


def _flash_forward(q, k, v, q_positions, kv_positions, scale, window,
                   causal, kv_chunk):
    """Online-softmax forward; returns (out (b,sq,hq*d), lse (b,h,g,sq))."""
    b, sq, hq, d = q.shape
    n_kv = k.shape[2]
    qg = _split_gqa(q, n_kv)
    kc, vc, pc, _ = _chunk_kv(k, v, kv_positions, kv_chunk)

    def step(carry, inputs):
        m, l, acc = carry
        k_i, v_i, kvpos_i = inputs
        mask_i = attention_mask(q_positions, kvpos_i, window, causal)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i,
                       preferred_element_type=jnp.float32) * scale
        s = s + mask_i[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_i.dtype), v_i,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    g = hq // n_kv
    # keep the online-softmax carry sequence-sharded (context parallelism)
    m0 = seq_hint(jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32), 3, 0)
    l0 = seq_hint(jnp.zeros((b, n_kv, g, sq), jnp.float32), 3, 0)
    a0 = seq_hint(jnp.zeros((b, n_kv, g, sq, d), jnp.float32), 3, 1)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq * d)
    return out.astype(q.dtype), lse


def attention_chunked(q, k, v, q_positions, kv_positions, scale: float,
                      window: int | None = None, causal: bool = True,
                      kv_chunk: int = 512):
    """Keyword-friendly wrapper over the custom-VJP flash attention."""
    return _attention_flash(q, k, v, q_positions, kv_positions, scale,
                            window, causal, kv_chunk)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _attention_flash(q, k, v, q_positions, kv_positions,
                     scale: float, window: int | None,
                     causal: bool, kv_chunk: int):
    """Flash-style attention (pure jnp) with a recompute backward.

    Forward scans KV chunks with an online softmax, never materializing the
    (Sq, Skv) score matrix.  The backward pass is a custom VJP that
    *recomputes* each chunk's probabilities from the saved log-sum-exp
    (the standard FlashAttention backward) — without it, reverse-mode AD
    through the scan would save every per-chunk probability block, which is
    exactly the O(Sq*Skv) memory the forward avoids.
    """
    out, _ = _flash_forward(q, k, v, q_positions, kv_positions, scale,
                            window, causal, kv_chunk)
    return out


def _flash_fwd_rule(q, k, v, q_positions, kv_positions, scale, window,
                    causal, kv_chunk):
    out, lse = _flash_forward(q, k, v, q_positions, kv_positions, scale,
                              window, causal, kv_chunk)
    return out, (q, k, v, q_positions, kv_positions, out, lse)


def _flash_bwd_rule(scale, window, causal, kv_chunk, res, dout):
    q, k, v, q_positions, kv_positions, out, lse = res
    b, sq, hq, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    g = hq // n_kv
    seqsh = lambda z: seq_hint(z, 1, 3)
    qg = seqsh(_split_gqa(q, n_kv).astype(jnp.float32))
    do = seqsh(dout.reshape(b, sq, n_kv, g, d).astype(jnp.float32))
    og = seqsh(out.reshape(b, sq, n_kv, g, d).astype(jnp.float32))
    # D_i = rowsum(dout * out)
    D = seq_hint(jnp.einsum("bqhgd,bqhgd->bhgq", do, og), 3, 0)
    lse = seq_hint(lse, 3, 0)

    kc, vc, pc, pad = _chunk_kv(k, v, kv_positions, kv_chunk)

    def step(dq, inputs):
        k_i, v_i, kvpos_i = inputs
        mask_i = attention_mask(q_positions, kvpos_i, window, causal)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i,
                       preferred_element_type=jnp.float32) * scale
        s = s + mask_i[None, None, None]
        p = jnp.exp(s - lse[..., None])                       # (b,h,g,q,k)
        dv_i = jnp.einsum("bhgqk,bqhgd->bkhd", p, do)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do,
                        v_i.astype(jnp.float32))
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                             k_i.astype(jnp.float32))
        dk_i = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)
        return dq, (dk_i, dv_i)

    dq0 = seqsh(jnp.zeros((b, sq, n_kv, g, d), jnp.float32))
    dq, (dkc, dvc) = jax.lax.scan(step, dq0, (kc, vc, pc))
    dk = dkc.transpose(1, 0, 2, 3, 4).reshape(b, -1, n_kv, d)
    dv = dvc.transpose(1, 0, 2, 3, 4).reshape(b, -1, n_kv, d)
    if pad:
        dk, dv = dk[:, :skv], dv[:, :skv]
    return (dq.reshape(b, sq, hq, d).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype), None, None)


_attention_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# KV cache (optionally int8-quantized: per-row-per-head absmax scales)


def init_kv_cache(batch: int, n_slots: int, n_kv_heads: int, head_dim: int,
                  dtype, quant: bool = False) -> dict:
    if quant:
        return {
            "k": jnp.zeros((batch, n_slots, n_kv_heads, head_dim), jnp.int8),
            "v": jnp.zeros((batch, n_slots, n_kv_heads, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, n_slots, n_kv_heads, 1),
                                 jnp.float32),
            "v_scale": jnp.zeros((batch, n_slots, n_kv_heads, 1),
                                 jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, n_slots, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, n_slots, n_kv_heads, head_dim), dtype),
    }


def kv_cache_specs(batch_spec, seq_spec, quant: bool = False) -> dict:
    spec = P(batch_spec, seq_spec, None, None)
    out = {"k": spec, "v": spec}
    if quant:
        out["k_scale"] = spec
        out["v_scale"] = spec
    return out


def quantize_rows(x: jax.Array):
    """(..., d) -> (int8 values, f32 absmax/127 scale with kept dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-9))
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# paged KV pool (block-table indexed; shared across the batch)


def init_paged_kv_pool(num_blocks: int, block_size: int, n_kv_heads: int,
                       head_dim: int, dtype, quant: bool = False) -> dict:
    """Block pool for full-attention layers: ``(NB, BS, Hkv, d)`` values
    shared by every sequence; per-sequence block tables map logical block
    -> physical block.  ``quant=True`` stores int8 values + f32 per-row
    per-head scales (cold blocks are immutable once full, so the whole
    pool can hold the quantized form — the numerics of the contiguous
    int8 cache, promoted to the paged layout)."""
    if quant:
        return {
            "k": jnp.zeros((num_blocks, block_size, n_kv_heads, head_dim),
                           jnp.int8),
            "v": jnp.zeros((num_blocks, block_size, n_kv_heads, head_dim),
                           jnp.int8),
            "k_scale": jnp.zeros((num_blocks, block_size, n_kv_heads, 1),
                                 jnp.float32),
            "v_scale": jnp.zeros((num_blocks, block_size, n_kv_heads, 1),
                                 jnp.float32),
        }
    return {
        "k": jnp.zeros((num_blocks, block_size, n_kv_heads, head_dim),
                       dtype),
        "v": jnp.zeros((num_blocks, block_size, n_kv_heads, head_dim),
                       dtype),
    }


def paged_row_indices(block_tables: jax.Array, positions: jax.Array,
                      block_size: int) -> jax.Array:
    """Flat pool-row index for each logical ``positions`` (B, N) entry.

    Out-of-table positions are clamped to the last table entry and null
    (<= 0) table entries resolve to block 0 — the engine reserves block 0
    as a scratch block that is never granted, so clamped/dead writes land
    there harmlessly.
    """
    bt = block_tables.astype(jnp.int32)
    mbs = bt.shape[1]
    blk = jnp.clip(positions // block_size, 0, mbs - 1)
    bids = jnp.maximum(jnp.take_along_axis(bt, blk, axis=1), 0)
    return bids * block_size + positions % block_size


def paged_write(cache: dict, k_new: jax.Array, v_new: jax.Array,
                block_tables: jax.Array, pos) -> dict:
    """Scatter Sq new K/V rows per sequence into the shared block pool at
    logical positions [pos, pos+Sq) via the block table.  Quantizes rows
    on write when the pool is int8 (identical per-row numerics to the
    contiguous int8 cache, so decoding stays token-identical to it)."""
    bs = cache["k"].shape[1]
    b, sq = k_new.shape[:2]
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos_arr[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    idx = paged_row_indices(block_tables, positions, bs).reshape(-1)
    if "k_scale" in cache:
        kq, ks = quantize_rows(k_new)
        vq, vs = quantize_rows(v_new)
        return {"k": _pool_scatter(cache["k"], idx, kq),
                "v": _pool_scatter(cache["v"], idx, vq),
                "k_scale": _pool_scatter(cache["k_scale"], idx, ks),
                "v_scale": _pool_scatter(cache["v_scale"], idx, vs)}
    return {"k": _pool_scatter(cache["k"], idx, k_new),
            "v": _pool_scatter(cache["v"], idx, v_new),
            **{kk: cache[kk] for kk in cache if kk not in ("k", "v")}}


def _pool_scatter(pool: jax.Array, flat_idx: jax.Array,
                  rows: jax.Array) -> jax.Array:
    """Write rows (..., H, d) at flat row indices of a (NB, BS, H, d) pool.
    Duplicate indices only arise from dead slots aimed at the scratch
    block, where any write order is acceptable."""
    nb, bs = pool.shape[:2]
    flat = pool.reshape((nb * bs,) + pool.shape[2:])
    flat = flat.at[flat_idx].set(
        rows.reshape((-1,) + pool.shape[2:]).astype(pool.dtype))
    return flat.reshape(pool.shape)


def paged_gather(cache: dict, block_tables: jax.Array, dtype):
    """Per-sequence contiguous (B, MBS*BS, H, d) K/V view of the pool
    (dequantized when int8).  Reference/CPU read path — on TPU the paged
    flash-decode kernel gathers block tiles in-kernel instead."""
    from repro.kernels.ref import gather_paged_kv_ref
    return gather_paged_kv_ref(
        cache["k"], cache["v"], block_tables,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        dtype=dtype)


def _slots_for(pos: jax.Array, i: int, n_slots: int, ring: bool) -> jax.Array:
    slot = jnp.asarray(pos, jnp.int32) + i
    return jnp.mod(slot, n_slots) if ring else slot


def _write_cache(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos, window: int | None) -> dict:
    """Write Sq new K/V rows starting at logical ``pos`` (ring if window).

    ``pos`` may be a scalar or a per-sequence (B,) array; the per-sequence
    case vmaps a dynamic_update_slice over the batch (lowers to a batched
    scatter, which GSPMD partitions along the batch axis).
    """
    sq = k_new.shape[1]
    n_slots = cache["k"].shape[1]
    ring = window is not None
    ck, cv = cache["k"], cache["v"]
    k_new = k_new.astype(ck.dtype)
    v_new = v_new.astype(cv.dtype)

    pos_arr = jnp.asarray(pos, jnp.int32)
    if pos_arr.ndim == 0:
        for i in range(sq):
            slot = _slots_for(pos_arr, i, n_slots, ring)
            ck = jax.lax.dynamic_update_slice(ck, k_new[:, i:i + 1],
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v_new[:, i:i + 1],
                                              (0, slot, 0, 0))
        return {"k": ck, "v": cv}

    def write_one(ck_b, cv_b, kn_b, vn_b, p):
        for i in range(sq):
            slot = _slots_for(p, i, n_slots, ring)
            ck_b = jax.lax.dynamic_update_slice(ck_b, kn_b[i:i + 1],
                                                (slot, 0, 0))
            cv_b = jax.lax.dynamic_update_slice(cv_b, vn_b[i:i + 1],
                                                (slot, 0, 0))
        return ck_b, cv_b

    ck, cv = jax.vmap(write_one)(ck, cv, k_new, v_new, pos_arr)
    return {"k": ck, "v": cv}


def _gather_rows(cache: dict, pos: jax.Array, sq: int,
                 window: int | None) -> dict:
    """Read the Sq rows that a subsequent write would clobber (ring only)."""
    n_slots = cache["k"].shape[1]

    def read_one(ck_b, cv_b, p):
        ks, vs = [], []
        for i in range(sq):
            slot = _slots_for(p, i, n_slots, True)
            ks.append(jax.lax.dynamic_slice(ck_b, (slot, 0, 0),
                                            (1,) + ck_b.shape[1:]))
            vs.append(jax.lax.dynamic_slice(cv_b, (slot, 0, 0),
                                            (1,) + cv_b.shape[1:]))
        return jnp.concatenate(ks), jnp.concatenate(vs)

    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32),
                               (cache["k"].shape[0],))
    k, v = jax.vmap(read_one)(cache["k"], cache["v"], pos_arr)
    return {"k": k, "v": v}


def restore_rejected_rows(cache: dict, saved: dict, pos, n_commit,
                          window: int | None) -> dict:
    """Undo ring-buffer writes of rejected speculative tokens.

    ``saved`` holds the pre-write rows for the Sq touched slots; row i is
    restored for sequences where ``i >= n_commit``.
    """
    sq = saved["k"].shape[1]
    n_slots = cache["k"].shape[1]
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32),
                               (cache["k"].shape[0],))
    nc = jnp.broadcast_to(jnp.asarray(n_commit, jnp.int32),
                          (cache["k"].shape[0],))

    def fix_one(ck_b, cv_b, sk_b, sv_b, p, n):
        for i in range(sq):
            slot = _slots_for(p, i, n_slots, True)
            cur_k = jax.lax.dynamic_slice(ck_b, (slot, 0, 0),
                                          (1,) + ck_b.shape[1:])
            cur_v = jax.lax.dynamic_slice(cv_b, (slot, 0, 0),
                                          (1,) + cv_b.shape[1:])
            keep = i < n
            new_k = jnp.where(keep, cur_k, sk_b[i:i + 1])
            new_v = jnp.where(keep, cur_v, sv_b[i:i + 1])
            ck_b = jax.lax.dynamic_update_slice(ck_b, new_k, (slot, 0, 0))
            cv_b = jax.lax.dynamic_update_slice(cv_b, new_v, (slot, 0, 0))
        return ck_b, cv_b

    ck, cv = jax.vmap(fix_one)(cache["k"], cache["v"], saved["k"],
                               saved["v"], pos_arr, nc)
    return {"k": ck, "v": cv}


def _prefill_ring(cache: dict, k_new: jax.Array, v_new: jax.Array,
                  window: int) -> dict:
    """Bulk-write the last ``window`` of a prefilled sequence into the ring."""
    s = k_new.shape[1]
    n_slots = cache["k"].shape[1]
    pj = ring_slot_positions(n_slots, s, window)  # logical pos per slot
    idx = jnp.clip(pj, 0, s - 1)
    ck = jnp.take(k_new, idx, axis=1).astype(cache["k"].dtype)
    cv = jnp.take(v_new, idx, axis=1).astype(cache["v"].dtype)
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# full attention layer application


def apply_attention(params: dict, x: jax.Array, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    rope_theta: float, use_rope: bool = True,
                    window: int | None = None,
                    cache: dict | None = None, pos=0,
                    phase: str = "prefill",
                    block_tables: jax.Array | None = None,
                    kv_chunk: int = 0,
                    spec_tree: dict | None = None) -> tuple:
    """One attention layer.

    phase="prefill"/"train": x is the full sequence; if ``cache`` is given it
    is (re)filled and returned.  phase="decode": x holds Sq (>=1) new tokens
    at logical positions [pos, pos+Sq); the cache is updated and attended.
    When ``block_tables`` is given (decode only), ``cache`` is a shared
    block *pool* and reads/writes are block-table indirect (paged KV).

    ``spec_tree`` (decode only) marks x as speculation-*tree* nodes: cache
    slots stay sequential but each node's RoPE position is ``pos - prev +
    depth`` (siblings are alternatives for the same step) and visibility
    inside the buffer follows the static ancestor mask (see
    :func:`repro.core.spec_decode.tree_spec`).  Requires full attention
    (``window`` must be None).

    Returns (out, new_cache).
    """
    b, sq, _ = x.shape
    scale = head_dim ** -0.5
    # pin the flat head dim (always divisible by the mesh) to the model
    # axis: this also pins the cotangent so dWq/dWk/dWv stay sharded
    U = P.UNCONSTRAINED
    pin = lambda z: shard_hint(z, U, U, "model")
    q = pin(x @ params["wq"]).reshape(b, sq, n_heads, head_dim)
    k = pin(x @ params["wk"]).reshape(b, sq, n_kv_heads, head_dim)
    v = pin(x @ params["wv"]).reshape(b, sq, n_kv_heads, head_dim)

    pos_arr = jnp.asarray(pos, jnp.int32)
    if pos_arr.ndim:
        q_positions = pos_arr[:, None] + jnp.arange(sq, dtype=jnp.int32)
    else:
        q_positions = pos_arr + jnp.arange(sq, dtype=jnp.int32)
    tree = spec_tree is not None and phase == "decode"
    if tree:
        if window is not None:
            raise ValueError("tree speculation needs full attention: a "
                             "sliding-window ring cannot hold a branched "
                             "buffer")
        t_prev = int(spec_tree["prev"])
        t_mask = np.asarray(spec_tree["mask"])
        t_depths = jnp.asarray(np.asarray(spec_tree["depths"]), jnp.int32)
        t_base = jnp.broadcast_to(pos_arr, (b,)) - t_prev
        # logical position = committed length + depth; the cache *slot*
        # stays the sequential [pos, pos+Sq) buffer order
        q_positions = t_base[:, None] + t_depths[None, :]
    if use_rope:
        sin, cos = rope_table(q_positions, head_dim, rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    if kv_chunk == 0:
        # training keeps smaller score blocks: the f32 (B,H,Sq,kc) chunk and
        # its backward twins are the peak-memory buffers at 4k x 128 heads
        kv_chunk = 128 if phase == "train" else 512

    saved = {}
    if phase in ("prefill", "train"):
        # context parallelism (when a sequence axis is active): shard the q
        # sequence so per-chip flash transients are Sq/axis_size; KV stays
        # batch-sharded (every chip scans all KV chunks).  Head counts of
        # the assigned archs (10, 36, 40...) often don't divide the mesh,
        # so sequence sharding is the portable choice (DESIGN.md §6).
        q = seq_hint(q, 1, 2)
        if seq_axis() == "model":
            k = shard_hint(k, "data", None, None, None)
            v = shard_hint(v, "data", None, None, None)
        out = attention_chunked(q, k, v, q_positions, q_positions, scale,
                                window=window, kv_chunk=kv_chunk)
        out = pin(out)  # flat-head on model -> dWo stays sharded
        new_cache = None
        if cache is not None:
            if window is not None and cache["k"].shape[1] < sq:
                new_cache = _prefill_ring(cache, k, v, window)
            else:  # bulk write of the whole prefix at offset 0
                zero = (0, 0, 0, 0)
                kw, vw = k, v
                new_cache = {}
                if "k_scale" in cache:  # int8 cache: quantize + store scales
                    kw, ks = quantize_rows(k)
                    vw, vs = quantize_rows(v)
                    new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                        cache["k_scale"], ks, zero)
                    new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                        cache["v_scale"], vs, zero)
                new_cache["k"] = jax.lax.dynamic_update_slice(
                    cache["k"], kw.astype(cache["k"].dtype), zero)
                new_cache["v"] = jax.lax.dynamic_update_slice(
                    cache["v"], vw.astype(cache["v"].dtype), zero)
    elif phase == "decode" and block_tables is not None:
        # paged pool: scatter the new rows through the block table, then
        # attend over the table's gathered view.  Full attention only —
        # ring (SWA) layers are window-bounded and stay per-slot.
        assert cache is not None and window is None
        new_cache = paged_write(cache, k, v, block_tables, pos_arr)
        if _use_paged_kernel() and (not tree or t_prev == 0):
            from repro.kernels import ops as kernel_ops
            # full-buffer tree verify (prev == 0): the kernel masks the
            # last Sq rows with per-node int32 ancestor bitmasks
            anc = (jnp.asarray(np.asarray(spec_tree["anc_bits"]))
                   if tree else None)
            out = kernel_ops.paged_decode_attention(
                q.transpose(0, 2, 1, 3), new_cache["k"], new_cache["v"],
                block_tables, jnp.broadcast_to(pos_arr, (b,)) + sq,
                k_scale=new_cache.get("k_scale"),
                v_scale=new_cache.get("v_scale"), scale=scale,
                anc_bits=anc)
            out = out.transpose(0, 2, 1, 3).reshape(b, sq, -1)
        else:
            k_read, v_read = paged_gather(new_cache, block_tables, q.dtype)
            if tree:
                mask = _tree_decode_mask(t_base, t_mask, k_read.shape[1])
            else:
                kv_positions = jnp.arange(k_read.shape[1], dtype=jnp.int32)
                mask = attention_mask(q_positions, kv_positions, None)
            out = attention_direct(q, k_read, v_read, mask, scale)
    elif phase == "decode":
        assert cache is not None
        n_slots = cache["k"].shape[1]
        ring = window is not None and n_slots <= window
        quant = "k_scale" in cache
        assert not (ring and quant), "int8 cache unsupported on ring buffers"
        if ring and sq > 1:
            # Multi-token verify on a ring buffer: writing first would
            # clobber rows still visible to the *earlier* in-flight tokens,
            # so attend over a [cache ++ new] concat view, then write.
            saved = _gather_rows(cache, pos_arr, sq, window)
            old_positions = ring_slot_positions(n_slots, pos_arr, n_slots)
            k_all = jnp.concatenate([cache["k"].astype(q.dtype), k], axis=1)
            v_all = jnp.concatenate([cache["v"].astype(q.dtype), v], axis=1)
            kv_positions = jnp.concatenate(
                [old_positions,
                 jnp.broadcast_to(q_positions, (x.shape[0], sq))], axis=1)
            mask = attention_mask(q_positions, kv_positions, window)
            out = attention_direct(q, k_all, v_all, mask, scale)
            new_cache = _write_cache(cache, k, v, pos_arr, window)
        else:
            if ring:
                saved = _gather_rows(cache, pos_arr, sq, window)
            if quant:
                kq, ks = quantize_rows(k)
                vq, vs = quantize_rows(v)
                vals = _write_cache({"k": cache["k"], "v": cache["v"]},
                                    kq, vq, pos_arr, None)
                scs = _write_cache({"k": cache["k_scale"],
                                    "v": cache["v_scale"]},
                                   ks, vs, pos_arr, None)
                new_cache = {"k": vals["k"], "v": vals["v"],
                             "k_scale": scs["k"], "v_scale": scs["v"]}
                k_read = dequantize(new_cache["k"], new_cache["k_scale"],
                                    q.dtype)
                v_read = dequantize(new_cache["v"], new_cache["v_scale"],
                                    q.dtype)
            else:
                new_cache = _write_cache(cache, k, v, pos_arr,
                                         window if ring else None)
                k_read = new_cache["k"].astype(q.dtype)
                v_read = new_cache["v"].astype(q.dtype)
            length = pos_arr + sq
            if tree:
                mask = _tree_decode_mask(t_base, t_mask, n_slots)
            else:
                if ring:
                    kv_positions = ring_slot_positions(n_slots, length,
                                                       n_slots)
                else:
                    kv_positions = jnp.arange(n_slots, dtype=jnp.int32)
                mask = attention_mask(q_positions, kv_positions, window)
            out = attention_direct(q, k_read, v_read, mask, scale)
    else:
        raise ValueError(phase)

    return out @ params["wo"], new_cache, saved


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)


def init_cross_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                         head_dim: int, dtype) -> dict:
    return init_attention(key, d_model, n_heads, n_kv_heads, head_dim, dtype)


def precompute_cross_kv(params: dict, enc_out: jax.Array, *,
                        n_kv_heads: int, head_dim: int) -> dict:
    b, s, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (enc_out @ params["wv"]).reshape(b, s, n_kv_heads, head_dim)
    return {"ck": k, "cv": v}


def apply_cross_attention(params: dict, x: jax.Array, cross_kv: dict, *,
                          n_heads: int, head_dim: int) -> jax.Array:
    b, sq, _ = x.shape
    scale = head_dim ** -0.5
    q = (x @ params["wq"]).reshape(b, sq, n_heads, head_dim)
    k, v = cross_kv["ck"].astype(q.dtype), cross_kv["cv"].astype(q.dtype)
    mask = jnp.zeros((sq, k.shape[1]), jnp.float32)  # no causal mask
    out = attention_direct(q, k, v, mask, scale)
    return out @ params["wo"]
