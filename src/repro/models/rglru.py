"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure::

    x ──ln──┬── w_y ── gelu ─────────────────┐
            └── w_x ── causal conv1d ── RG-LRU ──*──  w_out ── (+residual)

RG-LRU recurrence (all element-wise over the ``width`` channels)::

    r_t = sigmoid(x_t @ w_a + b_a)            # recurrence gate
    i_t = sigmoid(x_t @ w_i + b_i)            # input gate
    log a_t = -c * softplus(a_param) * r_t    # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Speculative decoding needs to *roll back* rejected tokens, so the multi-token
decode path returns the per-step state stack; ``commit`` selects the state at
the accepted position (see ``repro.core.spec_decode``).

State: ``{"h": (B, W) f32, "conv": (B, conv_width-1, W)}``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, seq_axis, shard_hint

_C = 8.0
_EPS = 1e-6


def init_rglru(key, d_model: int, width: int, conv_width: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    # a_param init so that a = exp(-c*softplus(a_param)) spans ~[0.9, 0.999]
    u = jax.random.uniform(ks[0], (width,), minval=0.9, maxval=0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / _C)).astype(jnp.float32)
    return {
        "w_y": dense_init(ks[1], d_model, width, dtype),
        "w_x": dense_init(ks[2], d_model, width, dtype),
        "w_out": dense_init(ks[3], width, d_model, dtype),
        "conv_w": (jax.random.normal(ks[4], (conv_width, width)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": dense_init(ks[5], width, width, dtype),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_i": dense_init(ks[6], width, width, dtype),
        "b_i": jnp.zeros((width,), jnp.float32),
        "a_param": a_param,
    }


def rglru_specs() -> dict:
    return {
        "w_y": P("data", "model"), "w_x": P("data", "model"),
        "w_out": P("model", "data"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "w_a": P("data", "model"), "b_a": P("model"),
        "w_i": P("data", "model"), "b_i": P("model"),
        "a_param": P("model"),
    }


def init_rglru_state(batch: int, width: int, conv_width: int, dtype) -> dict:
    return {"h": jnp.zeros((batch, width), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, width), dtype)}


def rglru_state_specs(batch_spec) -> dict:
    return {"h": P(batch_spec, "model"), "conv": P(batch_spec, None, "model")}


def _conv1d_causal(x: jax.Array, conv_state: jax.Array, w: jax.Array,
                   b: jax.Array):
    """Depthwise causal conv over time. x (B,S,W); state (B,cw-1,W).

    Returns (y (B,S,W), new_state).
    """
    cw = w.shape[0]
    full = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(cw):
        y = y + full[:, i:i + s] * w[cw - 1 - i]
    new_state = full[:, -(cw - 1):] if cw > 1 else conv_state
    return y + b, new_state


def _rglru_scan(params: dict, x: jax.Array, h0: jax.Array):
    """Run the RG-LRU over x (B,S,W) from state h0 (B,W) f32.

    Returns (y (B,S,W) f32, h_all (B,S,W) f32) — the full state stack (the
    output *is* the state, which makes rollback free).
    """
    wshard = (lambda z: shard_hint(z, "data", None, "model")) \
        if seq_axis() == "model" else (lambda z: z)
    xf = x.astype(jnp.float32)
    r = wshard(jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32)
                              + params["b_a"]))
    i = wshard(jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32)
                              + params["b_i"]))
    log_a = -_C * jax.nn.softplus(params["a_param"]) * r          # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), _EPS, 1.0)) * (i * xf)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    if seq_axis() == "model":
        h0 = shard_hint(h0, "data", "model")
    aT = jnp.swapaxes(a, 0, 1)        # (S,B,W) scan over time
    gT = jnp.swapaxes(gated, 0, 1)
    _, h_allT = jax.lax.scan(step, h0, (aT, gT))
    h_all = jnp.swapaxes(h_allT, 0, 1)
    return h_all, h_all


def apply_rglru_block(params: dict, x: jax.Array, state: dict):
    """Full recurrent block over x (B,S,D).

    Returns (out (B,S,D), new_state, state_stack) where ``state_stack`` holds
    per-step recurrent+conv states for speculative rollback:
    ``{"h": (B,S,W), "conv": (B,S,cw-1,W)}``.
    """
    # keep the width dim sharded on the model axis throughout the block so
    # the (B, S, W) recurrence intermediates stay 1/model_size per chip
    wshard = (lambda z: shard_hint(z, "data", None, "model")) \
        if seq_axis() == "model" else (lambda z: z)
    y_branch = wshard(jax.nn.gelu(x @ params["w_y"]))
    xb = wshard(x @ params["w_x"])
    cw = params["conv_w"].shape[0]
    conv_out, conv_final = _conv1d_causal(xb, state["conv"], params["conv_w"],
                                          params["conv_b"])
    h_out, h_stack = _rglru_scan(params, conv_out, state["h"])
    out = (h_out.astype(x.dtype) * y_branch) @ params["w_out"]
    new_state = {"h": h_stack[:, -1], "conv": conv_final}

    s = x.shape[1]
    state_stack = None
    if s <= 16:  # decode/verify path: keep per-step states for rollback
        full = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)
        conv_stack = jnp.stack(
            [full[:, i + 1:i + cw] for i in range(s)], axis=1)  # (B,S,cw-1,W)
        # index 0 = the pre-step state, so commit(n=0) is expressible
        state_stack = {
            "h": jnp.concatenate([state["h"][:, None], h_stack], axis=1),
            "conv": jnp.concatenate(
                [state["conv"][:, None].astype(conv_stack.dtype), conv_stack],
                axis=1),
        }
    return out, new_state, state_stack


def select_rglru_state(state_stack: dict, index: jax.Array) -> dict:
    """Pick per-sequence state at step ``index`` (B,) from the stack."""
    b = index.shape[0]
    bi = jnp.arange(b)
    return {"h": state_stack["h"][bi, index],
            "conv": state_stack["conv"][bi, index]}
