"""Basic building blocks: init helpers, norms, RoPE, MLPs, embeddings.

Conventions
-----------
* All ``init_*`` functions return nested dicts of arrays; the matching
  ``*_specs`` functions return the same structure of ``PartitionSpec``.
* Weight matrices are stored ``(in_features, out_features)`` so the forward
  is ``x @ w``.
* ``compute_dtype`` is carried by the caller; params are stored in the
  config dtype and normed/accumulated in float32 where it matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


import contextvars

# Which mesh axis (if any) the *sequence* dimension of activations shards
# over inside attention / the residual carry.  None = no sequence
# parallelism (pure FSDP profiles where the batch covers the whole mesh).
_SEQ_AXIS = contextvars.ContextVar("seq_axis", default="model")


class sequence_sharding:
    """Context manager selecting the sequence-parallel axis (or None)."""

    def __init__(self, axis):
        self.axis = axis

    def __enter__(self):
        self._tok = _SEQ_AXIS.set(self.axis)
        return self

    def __exit__(self, *exc):
        _SEQ_AXIS.reset(self._tok)
        return False


def seq_axis():
    return _SEQ_AXIS.get()


def ambient_mesh():
    """The mesh of the enclosing ``with mesh:`` scope, or None.

    ``jax.sharding.get_abstract_mesh`` only exists on newer jax; on older
    releases fall back to the thread-resources physical mesh that the
    ``Mesh`` context manager installs."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
    else:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


def shard_hint(x, *spec):
    """with_sharding_constraint that no-ops when the named axes are absent
    from the ambient mesh (so the same model code runs on 1 CPU device and
    on the production mesh)."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    for s in spec:
        for n in ((s,) if not isinstance(s, tuple) else s):
            if n is None or n is P.UNCONSTRAINED:
                continue
            if n not in names:
                return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def seq_hint(x, ndim_before: int, ndim_after: int):
    """Shard dim ``ndim_before`` (the sequence dim) on the seq axis, leaving
    every other dim unconstrained; no-op when sequence parallelism is off."""
    ax = seq_axis()
    if ax is None:
        return x
    U = P.UNCONSTRAINED
    spec = [U] * ndim_before + [ax] + [U] * ndim_after
    return shard_hint(x, *spec)


def fsdp_axes():
    """The mesh axes weights' contraction dims shard over (podified on the
    multi-pod mesh) — None when no mesh is active."""
    mesh = ambient_mesh()
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def gather_seq(x, seq_dim: int = 1):
    """Force the sequence dim replicated (Megatron-SP style gather before a
    weight matmul whose output dim shards on the same axis); no-op unless
    the seq axis is 'model' (the conflicting case)."""
    if seq_axis() != "model":
        return x
    U = P.UNCONSTRAINED
    spec = [U] * x.ndim
    spec[seq_dim] = None
    return shard_hint(x, *spec)


# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (matches common LLM practice)."""
    std = d_in ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, (d_in, d_out))).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_specs(kind: str) -> dict:
    p = {"scale": P(None)}
    if kind == "layernorm":
        p["bias"] = P(None)
    return p


def apply_norm(params: dict, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps)
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings


def rope_table(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """sin/cos tables for integer ``positions`` (any shape).

    Returns (sin, cos) with shape ``positions.shape + (head_dim//2,)`` in f32.
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate ``x`` (..., S, n_heads, head_dim) by per-position tables.

    ``sin``/``cos`` have shape (..., S, head_dim//2) and broadcast over the
    heads axis.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]  # add head axis
    c = cos[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }


def mlp_specs(activation: str) -> dict:
    if activation in ("swiglu", "geglu"):
        return {"w_gate": P("data", "model"), "w_up": P("data", "model"),
                "w_down": P("model", "data")}
    return {"w_up": P("data", "model"), "w_down": P("model", "data")}


def _act(h_gate, activation: str):
    if activation == "swiglu":
        return jax.nn.silu(h_gate)
    if activation == "geglu":
        return jax.nn.gelu(h_gate)
    if activation == "gelu":
        return jax.nn.gelu(h_gate)
    if activation == "relu2":
        return jnp.square(jax.nn.relu(h_gate))
    raise ValueError(activation)


def apply_mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    # Pin the hidden dim to the model axis: this also ties the *cotangent*
    # sharding in reverse-mode AD, keeping dW = x^T dy sharded instead of a
    # full (D, F) f32 buffer per layer.
    U = P.UNCONSTRAINED
    pin = lambda h: shard_hint(h, *([U] * (h.ndim - 1)), "model")
    if "w_gate" in params:
        h = pin(_act(x @ params["w_gate"], activation) * (x @ params["w_up"]))
    else:
        h = pin(_act(x @ params["w_up"], activation))
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# embeddings / unembedding


def init_embedding(key, vocab: int, d_model: int, dtype, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": embed_init(k1, vocab, d_model, dtype)}
    if not tie:
        p["head"] = dense_init(k2, d_model, vocab, dtype)
    return p


def embedding_specs(tie: bool, vocab: int = 0, d_model: int = 0,
                    model_size: int = 16, data_size: int = 16) -> dict:
    """Vocab-on-model sharding, falling back when the vocab doesn't divide
    the axis (e.g. whisper's 51865)."""
    def spec(axes_by_dim):
        out = []
        for size, pref in axes_by_dim:
            ax = None
            for cand, cand_size in pref:
                if size == 0 or cand is None or size % cand_size == 0:
                    ax = cand
                    break
            out.append(ax)
        return P(*out)

    v_axes = ((vocab, (("model", model_size), (None, 1))),
              (d_model, (("data", data_size), (None, 1))))
    p = {"tok": spec(v_axes)}
    if not tie:
        p["head"] = spec(((d_model, (("data", data_size), (None, 1))),
                          (vocab, (("model", model_size), (None, 1)))))
    return p


def embed_tokens(params: dict, tokens: jax.Array) -> jax.Array:
    return params["tok"][tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    w = params.get("head")
    if w is None:
        w = params["tok"].T
    return (x @ w).astype(jnp.float32)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Classic sinusoid table (whisper encoder positions), (n, d) f32."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
