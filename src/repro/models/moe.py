"""Mixture-of-Experts FFN with capacity-based token dispatch.

Routing follows the Switch/Mixtral recipe: softmax router, top-k experts per
token, per-expert capacity ``C = ceil(tokens * top_k / E * capacity_factor)``
with overflow dropped, gate weights renormalized over the kept experts.

Distribution modes (selected per call):

* ``local`` — no mesh / single device: dispatch + grouped einsum locally.
* ``ep`` — expert parallel: experts sharded over the ``model`` mesh axis,
  tokens sharded over (data=batch, model=sequence); each chip dispatches its
  local tokens into an ``(E, C, D)`` buffer and a tiled ``all_to_all``
  exchanges rows so each chip computes only its resident experts.  This is
  the MoE analogue of the paper's per-expert weight-streaming unit.
  Requires ``E % model_axis == 0`` and ``S % model_axis == 0``.
* ``tp`` — tensor parallel fallback (decode steps, or E not divisible, e.g.
  Mixtral's 8 experts on a 16-wide axis): every chip holds all experts with
  the hidden dim sharded over ``model``; a ``psum`` completes the
  down-projection.

All modes share ``_dispatch``/``_combine``/``_expert_ffn`` so the math is
identical; ``ep``/``tp`` run inside ``jax.shard_map``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _act, dense_init


def _axis_size(axis_name):
    """``jax.lax.axis_size`` is newer jax; psum(1) is the portable way to
    read a mapped axis' size inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions: the public ``jax.shard_map`` (with
    ``check_vma``) is newer; older releases have the experimental one
    (with ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# params


def init_moe(key, d_model: int, d_ff: int, n_experts: int, activation: str,
             dtype) -> dict:
    ks = jax.random.split(key, 4)
    gated = activation in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_up": _expert_init(ks[1], n_experts, d_model, d_ff, dtype),
        "w_down": _expert_init(ks[2], n_experts, d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = _expert_init(ks[3], n_experts, d_model, d_ff, dtype)
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    std = d_in ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, (e, d_in, d_out))).astype(dtype)


def moe_storage_specs(activation: str, n_experts: int, model_size: int) -> dict:
    """At-rest sharding for MoE params (what the launcher places)."""
    ep = model_size > 0 and n_experts % model_size == 0
    if ep:
        w, wd = P("model", "data", None), P("model", None, "data")
    else:
        w, wd = P(None, "data", "model"), P(None, "model", "data")
    p = {"router": P(None, None), "w_up": w, "w_down": wd}
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = w
    return p


def _view_specs(activation: str, mode: str) -> dict:
    """Partitioning as seen by the shard_map body."""
    if mode == "ep_psum":
        # matches the at-rest storage exactly: zero resharding at entry
        w, wd = P("model", "data", None), P("model", None, "data")
        router = P("data", None)
    elif mode == "ep":
        w, wd = P("model", None, None), P("model", None, None)
        router = P(None, None)
    else:
        w, wd = P(None, None, "model"), P(None, "model", None)
        router = P(None, None)
    p = {"router": router, "w_up": w, "w_down": wd}
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = w
    return p


# ---------------------------------------------------------------------------
# shared routing math (token-local, used identically in every mode)


def _route(router_w, x_flat, n_experts: int, top_k: int):
    """Top-k routing. Returns (expert_idx (N,k), gate (N,k) f32)."""
    logits = x_flat.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return idx, gate


def _capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    """cf >= n_experts/top_k (or cf=inf) gives dropless dispatch."""
    if cf == float("inf") or cf * top_k >= n_experts:
        return n_tokens
    cap = int(n_tokens * top_k * cf / n_experts) + 1
    return max(cap, 1)


def _dispatch(x_flat, idx, n_experts: int, capacity: int):
    """Scatter tokens into per-expert capacity buffers.

    Returns (buf (E, C, D), slot (N, k) int32 — slot < 0 means dropped).
    """
    n, k = idx.shape
    flat_e = idx.reshape(-1)                               # (N*k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot              # 1-based rank
    slot = (pos.sum(-1) - 1).astype(jnp.int32)             # (N*k,)
    keep = slot < capacity
    slot = jnp.where(keep, slot, -1)
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_s = jnp.where(keep, slot, 0)
    buf = jnp.zeros((n_experts, capacity, x_flat.shape[-1]), x_flat.dtype)
    buf = buf.at[safe_e, safe_s].add(
        jnp.where(keep[:, None], x_flat[tok], 0).astype(x_flat.dtype))
    return buf, slot.reshape(n, k)


def _combine(y_buf, idx, slot, gate):
    """Gather expert outputs back to token order, weighted by gates."""
    n, k = idx.shape
    keep = slot >= 0
    safe_s = jnp.where(keep, slot, 0)
    picked = y_buf[idx.reshape(-1), safe_s.reshape(-1)].reshape(n, k, -1)
    picked = jnp.where(keep[..., None], picked, 0)
    return jnp.einsum("nkd,nk->nd", picked.astype(jnp.float32),
                      gate).astype(y_buf.dtype)


def _expert_ffn(params, buf, activation: str):
    """(E, C, D) -> (E, C, D) grouped FFN."""
    if "w_gate" in params:
        h = _act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]), activation)
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    else:
        h = _act(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]), activation)
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


# ---------------------------------------------------------------------------
# mode bodies


def _moe_local(params, x_flat, *, n_experts, top_k, capacity_factor,
               activation):
    n = x_flat.shape[0]
    cap = _capacity(n, top_k, n_experts, capacity_factor)
    idx, gate = _route(params["router"], x_flat, n_experts, top_k)
    buf, slot = _dispatch(x_flat, idx, n_experts, cap)
    y = _expert_ffn(params, buf, activation)
    return _combine(y, idx, slot, gate)


def _moe_ep_body(params, x_flat, *, n_experts, top_k, capacity_factor,
                 activation, model_axis="model"):
    """Per-chip body: tokens local shard, experts sharded on ``model``."""
    n = x_flat.shape[0]
    msize = _axis_size(model_axis)
    cap = _capacity(n, top_k, n_experts, capacity_factor)
    idx, gate = _route(params["router"], x_flat, n_experts, top_k)
    buf, slot = _dispatch(x_flat, idx, n_experts, cap)       # (E, C, D)
    # each chip keeps experts [m*E/msize, ...); swap rows for experts
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1,
                             tiled=True)                     # (E_loc, C*m, D)
    y = _expert_ffn(params, buf, activation)
    y = jax.lax.all_to_all(y, model_axis, split_axis=1, concat_axis=0,
                           tiled=True)                       # (E, C, D)
    return _combine(y, idx, slot, gate)


def _moe_ep_psum_body(params, x_flat, *, n_experts, top_k, capacity_factor,
                      activation, model_axis="model", data_axis="data"):
    """Fully weight-stationary decode MoE (§Perf hillclimb #3).

    Tokens are few at decode time, so the token block is replicated and
    its *feature* dim sharded over ``data`` (matching the experts' at-rest
    P('model','data',·) sharding exactly — zero resharding at entry).
    Each chip computes the partial up/gate products of its resident
    experts from its D-shard, psums the (E_loc, C, F) partials over
    ``data`` BEFORE the nonlinearity (exact), applies SwiGLU, projects
    down to its local D-shard, and a psum over ``model`` combines expert
    contributions.  Collective traffic is a few MB of activations per
    layer; the GBs of expert weights never move.
    """
    n = x_flat.shape[0]                       # x_flat: (N, D_local)
    msize = _axis_size(model_axis)
    e_loc = n_experts // msize
    cap = _capacity(n, top_k, n_experts, capacity_factor)

    # routing: partial logits over the local D shard, psum over data
    logits = jax.lax.psum(
        x_flat.astype(jnp.float32) @ params["router"], data_axis)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    buf, slot = _dispatch(x_flat, idx, n_experts, cap)   # (E, C, D_loc)
    m_idx = jax.lax.axis_index(model_axis)
    buf_loc = jax.lax.dynamic_slice_in_dim(buf, m_idx * e_loc, e_loc, 0)

    hu = jax.lax.psum(
        jnp.einsum("ecd,edf->ecf", buf_loc, params["w_up"],
                   preferred_element_type=jnp.float32), data_axis)
    if "w_gate" in params:
        hg = jax.lax.psum(
            jnp.einsum("ecd,edf->ecf", buf_loc, params["w_gate"],
                       preferred_element_type=jnp.float32), data_axis)
        h = _act(hg, activation) * hu
    else:
        h = _act(hu, activation)
    y_loc = jnp.einsum("ecf,efd->ecd", h.astype(buf_loc.dtype),
                       params["w_down"])            # (E_loc, C, D_loc)
    y = jnp.zeros((n_experts, cap, x_flat.shape[-1]), y_loc.dtype)
    y = jax.lax.dynamic_update_slice_in_dim(y, y_loc, m_idx * e_loc, 0)
    y = jax.lax.psum(y, model_axis)
    return _combine(y, idx, slot, gate)


def _moe_tp_body(params, x_flat, *, n_experts, top_k, capacity_factor,
                 activation, model_axis="model"):
    """Per-chip body: all experts resident, hidden dim sharded on model."""
    n = x_flat.shape[0]
    cap = _capacity(n, top_k, n_experts, capacity_factor)
    idx, gate = _route(params["router"], x_flat, n_experts, top_k)
    buf, slot = _dispatch(x_flat, idx, n_experts, cap)
    y = _expert_ffn(params, buf, activation)    # partial over hidden shards
    y = jax.lax.psum(y, model_axis)
    return _combine(y, idx, slot, gate)


# ---------------------------------------------------------------------------
# public entry


def select_moe_mode(n_experts: int, seq_len: int, mesh) -> str:
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return "local"
    msize = mesh.shape["model"]
    if msize == 1:
        return "local"
    if n_experts % msize == 0:
        # all-to-all EP when the sequence can spread over 'model';
        # expert-stationary psum EP for decode steps (S < msize)
        return "ep" if seq_len % msize == 0 else "ep_psum"
    return "tp"


def apply_moe(params: dict, x: jax.Array, *, n_experts: int, top_k: int,
              activation: str, mesh=None, capacity_factor: float = 2.0,
              batch_axis="data", pod_axis=None) -> jax.Array:
    """MoE FFN over x (B, S, D)."""
    b, s, d = x.shape
    mode = select_moe_mode(n_experts, s, mesh)
    kw = dict(n_experts=n_experts, top_k=top_k,
              capacity_factor=capacity_factor, activation=activation)

    if mode == "local":
        return _moe_local(params, x.reshape(-1, d), **kw).reshape(b, s, d)

    body = {"ep": _moe_ep_body, "ep_psum": _moe_ep_psum_body,
            "tp": _moe_tp_body}[mode]
    bspec = (pod_axis, batch_axis) if pod_axis else batch_axis
    # ep: sequence sharded over model so token work is spread;
    # ep_psum (decode): token block replicated, feature dim on 'data';
    # tp: tokens replicated over model
    if mode == "ep_psum":
        x_spec = P(None, None, "data")
    else:
        x_spec = P(bspec, "model" if mode == "ep" else None, None)

    def shard_fn(p, xx):
        out = body(p, xx.reshape(-1, xx.shape[-1]), **kw)
        return out.reshape(xx.shape)

    return _shard_map(
        shard_fn, mesh,
        (_view_specs(activation, mode), x_spec), x_spec)(params, x)
