"""Data pipeline: synthetic token streams (for examples/benchmarks) and a
simple packed-LM batcher over token files.

The paper's workloads are offline batch-inference datasets (HumanEval,
C-Eval, SummEval, SAMSum); we model them with prompt-length distributions
matching Table 2 so planner/simulator inputs are faithful without shipping
the datasets themselves.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenDataset:
    """A set of prompts (ragged) + dataset statistics (paper Table 2)."""
    name: str
    prompts: list          # list[np.ndarray] of token ids
    s_avg: float
    s_max: int
    s_std: float

    @property
    def n(self):
        return len(self.prompts)


# Paper Table 2 statistics.
DATASET_STATS = {
    "humaneval": dict(s_avg=157.54, s_max=437, s_std=72.46),
    "ceval": dict(s_avg=165.46, s_max=483, s_std=103.18),
    "summeval": dict(s_avg=503.02, s_max=783, s_std=138.68),
    "samsum": dict(s_avg=168.10, s_max=1144, s_std=120.53),
}


def synthetic_dataset(name: str, n_prompts: int = 64, vocab: int = 32000,
                      seed: int = 0) -> TokenDataset:
    """Prompts with the named paper-dataset's length distribution."""
    stats = DATASET_STATS[name]
    rng = np.random.default_rng(seed)
    lengths = np.clip(
        rng.normal(stats["s_avg"], stats["s_std"], n_prompts).astype(int),
        8, stats["s_max"])
    prompts = [rng.integers(0, vocab, int(l)).astype(np.int32)
               for l in lengths]
    return TokenDataset(name, prompts, **stats)


def pad_batch(prompts: list, pad_to: int | None = None,
              pad_id: int = 0) -> np.ndarray:
    """Left-pad prompts to a common length (common-length batches)."""
    n = max(len(p) for p in prompts)
    n = pad_to or n
    out = np.full((len(prompts), n), pad_id, np.int32)
    for i, p in enumerate(prompts):
        out[i, n - len(p):] = p[:n]
    return out


def make_lm_batches(batch: int, seq: int, vocab: int, seed: int = 0,
                    structured: bool = True):
    """Infinite iterator of {'tokens': (B, S)} LM batches.

    ``structured=True`` makes the stream learnable (arithmetic token
    sequences + noise) so training-loss curves actually go down in the
    end-to-end example.
    """
    rng = np.random.default_rng(seed)
    while True:
        if structured:
            start = rng.integers(0, vocab, (batch, 1))
            step = rng.integers(1, 7, (batch, 1))
            toks = (start + step * np.arange(seq)[None, :]) % vocab
            noise = rng.random((batch, seq)) < 0.02
            toks = np.where(noise, rng.integers(0, vocab, (batch, seq)), toks)
        else:
            toks = rng.integers(0, vocab, (batch, seq))
        yield {"tokens": toks.astype(np.int32)}
