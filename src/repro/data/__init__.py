from repro.data.pipeline import (TokenDataset, make_lm_batches,
                                 synthetic_dataset)
