"""Discrete-event simulator of the SpecOffload pipeline + baselines.

Used to reproduce the paper's measured results (Figs 1/2/5/6/8, Tables 3/4)
on hardware we don't have.  The SpecOffload model reuses the ParaSpec
planner's latency equations (which were calibrated against Table 3);
ablations modify the pipeline structure, not the constants:

* ``serial_sd``  — speculative decoding *outside* the pipeline: draft runs
  serially between target rounds (no overlap) and its weights/KV must be
  streamed in and out each round (the paper's "loosely coupled" mode).
* ``no_sd``      — the pipeline without a draft model (FlexGen-like
  schedule but with our prefill/batching).
* ``no_policy``  — a deliberately bad policy (the paper uses a random one).

It also emits a decode-phase **timeline** of GPU-busy intervals so the
Fig 6/7 utilization/periodicity plots can be reproduced.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.planner import (ParaSpecPlanner, Policy, Workload,
                                dense_flops_per_token, kv_bytes_per_token,
                                layer_ffn_bytes, attn_flops_per_token)
from repro.core.spec_decode import expected_generated
from repro.sim.baselines import BASELINES, SystemResult
from repro.sim.hardware import HardwareSpec


@dataclass
class Timeline:
    """GPU-busy intervals (start, end, kind) during one decode window."""
    events: list = field(default_factory=list)
    horizon: float = 0.0

    def busy_fraction(self) -> float:
        busy = sum(e - s for s, e, _ in self.events)
        return busy / max(self.horizon, 1e-9)


def simulate_specoffload(target: ModelConfig, draft: ModelConfig,
                         hw: HardwareSpec, wl: Workload, pol: Policy,
                         mode: str = "full") -> SystemResult:
    """mode: full | serial_sd | no_sd | no_policy."""
    planner = ParaSpecPlanner(target, draft, hw)
    rep = planner.evaluate(pol, wl)
    m = pol.n_cand
    e_n = rep.expected_tokens
    bs = pol.bs_decode * 2

    if mode == "no_sd":
        # no draft: one token per round.  The CPU attention still reads the
        # whole KV working set per round and the FFN stream is unchanged,
        # so the round costs nearly as much as a verify round but yields 1
        # token instead of E[n] — that is the paper's whole point.
        ctx = wl.prompt_len + wl.gen_len / 2
        kv_read = pol.bs_decode * ctx * kv_bytes_per_token(target)
        t_attn = max(pol.bs_decode * attn_flops_per_token(target, int(ctx))
                     / hw.host_flops,
                     kv_read / (hw.host_mem_bw * hw.host_attn_eff))
        t_stream = target.n_layers * layer_ffn_bytes(target) / hw.h2d_bw
        t_gpu = pol.bs_decode * dense_flops_per_token(target) \
            / hw.accel_flops
        t_round = max(t_attn, t_stream) + t_gpu
        t_dec = 2 * wl.gen_len * t_round
        thr = bs * wl.gen_len / (rep.t_prefill + t_dec)
        from repro.sim.baselines import nvsmi_util
        util = nvsmi_util(t_gpu / t_round, min(t_stream, t_round) / t_round)
        return SystemResult("specoffload[no_sd]", thr, util,
                            {"t_round": t_round})

    if mode == "serial_sd":
        # draft runs between target rounds; its weights+KV stream in/out
        draft_io = 2 * draft.param_bytes() / hw.h2d_bw
        t_round = rep.t_target + rep.t_draft + draft_io
        n_iter = math.ceil(wl.gen_len / e_n)
        t_dec = 2 * n_iter * t_round
        thr = bs * wl.gen_len / (rep.t_prefill + t_dec)
        from repro.sim.baselines import nvsmi_util
        util = nvsmi_util((rep.detail["t_ffn_gpu"] + rep.t_draft) / t_round,
                          rep.detail["t_ffn_stream"] / t_round)
        return SystemResult("specoffload[serial_sd]", thr, util,
                            {"t_round": t_round, "draft_io": draft_io})

    thr = rep.throughput
    util = _gpu_util_full(rep)
    name = "specoffload" if mode == "full" else f"specoffload[{mode}]"
    return SystemResult(name, thr, util,
                        {"t_round": rep.detail["t_round"],
                         "t_draft": rep.t_draft,
                         "t_target": rep.t_target,
                         "E[n]": e_n,
                         "t_prefill": rep.t_prefill,
                         "t_decode": rep.t_decode})


def _gpu_util_full(rep) -> float:
    """Draft compute + target FFN/verify compute over the round, mapped to
    the nvidia-smi-style metric (see sim.baselines.nvsmi_util)."""
    from repro.sim.baselines import nvsmi_util
    t_round = rep.detail["t_round"]
    busy = min(rep.t_draft + rep.detail["t_ffn_gpu"], t_round)
    io = min(rep.detail["t_ffn_stream"], t_round)
    return nvsmi_util(busy / t_round, io / t_round * (1 - busy / t_round))


# ---------------------------------------------------------------------------
# paper-table drivers


def end_to_end(target: ModelConfig, draft: ModelConfig, hw: HardwareSpec,
               wl: Workload, pol: Policy) -> dict:
    """Fig 5: SpecOffload vs the four baselines."""
    out = {}
    spec = simulate_specoffload(target, draft, hw, wl, pol)
    out["specoffload"] = spec
    for name, fn in BASELINES.items():
        out[name] = fn(target, hw, wl.prompt_len, wl.gen_len)
    return out


def ablation(target: ModelConfig, draft: ModelConfig, hw: HardwareSpec,
             wl: Workload, pol: Policy, bad_pol: Policy) -> dict:
    """Table 4: all-opt vs no-policy vs serial-SD vs no-SD."""
    return {
        "all": simulate_specoffload(target, draft, hw, wl, pol),
        "no_policy": simulate_specoffload(target, draft, hw, wl, bad_pol,
                                          mode="no_policy"),
        "serial_sd": simulate_specoffload(target, draft, hw, wl, pol,
                                          mode="serial_sd"),
        "no_sd": simulate_specoffload(target, draft, hw, wl, pol,
                                      mode="no_sd"),
    }


def memory_sweep(target: ModelConfig, hw: HardwareSpec, wl: Workload,
                 fractions) -> list:
    """Fig 2: throughput (FlexGen-style decode) vs pinned-weight fraction.

    The total stream volume per step is (1 - pinned) of the FFN bytes;
    because the model is far larger than HBM, even a 5x memory reduction
    barely moves (1 - pinned) — the paper's "marginal utility" effect.
    """
    rows = []
    full = target.n_layers * layer_ffn_bytes(target)
    for frac in fractions:
        pinned_bytes = frac * hw.accel_mem_bytes
        pinned = min(pinned_bytes / full, 1.0)
        t_stream = full * (1 - pinned) / hw.h2d_bw
        ctx = wl.prompt_len + wl.gen_len / 2
        bs = 64
        kv_read = bs * ctx * kv_bytes_per_token(target)
        t_cpu = kv_read / (hw.host_mem_bw * hw.host_attn_eff)
        thr = bs / max(t_stream, t_cpu)
        rows.append({"mem_gib": pinned_bytes / 2 ** 30,
                     "pinned_frac": pinned, "throughput": thr})
    return rows


def disk_mode(target: ModelConfig, draft: ModelConfig, hw: HardwareSpec,
              wl: Workload, pol: Policy,
              os_reserve: float = 24 * 2 ** 30,
              disk_eff: float = 0.25) -> dict:
    """Fig 8: throughput when host memory can't hold the weights.

    Model assumptions (documented in EXPERIMENTS.md): everything that does
    not fit in (host - KV cache - OS reserve) streams from disk each round,
    at ``disk_eff * disk_read_bw`` effective throughput (layer-granular
    reads don't reach sequential-read bandwidth), serialized with the
    host->device stream since both cross the host memory bus.
    """
    spec = simulate_specoffload(target, draft, hw, wl, pol)
    ctx = wl.prompt_len + wl.gen_len
    kv_host = 2 * pol.bs_decode * ctx * kv_bytes_per_token(target)
    w = target.param_bytes()
    host_avail = hw.host_mem_bytes - kv_host - os_reserve
    disk_bytes = max(0.0, w - host_avail)
    t_round = spec.detail["t_round"]
    t_disk = disk_bytes / (hw.disk_read_bw * disk_eff)
    t_round_disk = max(t_round, t_round - spec.detail.get("t_target", 0)
                       + t_disk) + t_disk * 0.2   # eviction writes
    thr = spec.throughput * t_round / max(t_round_disk, 1e-9)
    return {"no_disk": spec.throughput, "disk": thr,
            "ratio": thr / spec.throughput,
            "disk_bytes_gib": disk_bytes / 2 ** 30}


def decode_timeline(target: ModelConfig, draft: ModelConfig,
                    hw: HardwareSpec, wl: Workload, pol: Policy,
                    n_rounds: int = 8) -> Timeline:
    """Fig 6/7: GPU-busy intervals across decode rounds (the ~26 s draft
    burst + ~2 s idle gap periodicity)."""
    planner = ParaSpecPlanner(target, draft, hw)
    rep = planner.evaluate(pol, wl)
    t_round = rep.detail["t_round"]
    tl = Timeline(horizon=n_rounds * t_round)
    t = 0.0
    for _ in range(n_rounds):
        busy_draft = min(rep.t_draft, t_round)
        tl.events.append((t, t + busy_draft, "draft"))
        t_ffn = rep.detail["t_ffn_gpu"]
        tl.events.append((t + t_round - t_ffn, t + t_round, "target_ffn"))
        t += t_round
    return tl
