"""Hardware specifications.

``Env #1`` / ``Env #2`` replicate the paper's Table 1 (RTX 4090 + PCIe 3/4)
so the simulator and the ParaSpec planner can be validated against the
paper's measured numbers.  ``TPU_V5E`` is the target platform for the JAX
engine and the roofline analysis (constants from the assignment brief).
"""
from __future__ import annotations

from dataclasses import dataclass

GB = 1024 ** 3
TFLOPS = 1e12


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    # accelerator
    accel_flops: float            # effective matmul FLOP/s (decode-size GEMMs)
    accel_mem_bytes: float
    accel_mem_bw: float           # HBM bytes/s
    # host
    host_flops: float             # effective CPU GEMM FLOP/s
    host_mem_bytes: float
    host_mem_bw: float = 60 * GB  # effective DRAM bandwidth (CPU attention
                                  # is memory-bound: ~1 FLOP/byte)
    # Effective fraction of host_mem_bw that framework-level CPU attention
    # achieves (HF/torch bf16: repeat_kv copies, dtype conversions, NUMA).
    # Calibrated against the paper's Table 3 Compute(C) column.
    host_attn_eff: float = 0.012
    # interconnect host<->accelerator
    h2d_bw: float = 12.5 * GB     # bytes/s host -> accelerator
    d2h_bw: float = 12.5 * GB
    # disk tier
    disk_read_bw: float = 3.5 * GB
    disk_write_bw: float = 1.7 * GB
    # large-GEMM (prefill) effective FLOP/s; 0 -> 1.33 * accel_flops
    accel_flops_prefill: float = 0.0
    # multi-chip links (TPU)
    ici_bw: float = 0.0


# Paper Table 1.  PCIe 3.0 x16 ~ 12.5 GB/s effective; PCIe 4.0 x16 ~ 25 GB/s.
# CPU effective GEMM throughput estimated from the paper's runtime breakdown
# (Table 3): decode-phase CPU attention dominates at ~0.1-0.2 TFLOP/s.
ENV1 = HardwareSpec(
    name="Env#1 RTX4090 PCIe3 i9-10980XE 256G",
    accel_flops=82.6 * TFLOPS * 0.6,   # fp16 w/ realistic efficiency
    accel_mem_bytes=24 * GB,
    accel_mem_bw=1008 * GB,
    host_flops=0.45 * TFLOPS,          # 18-core AVX-512 GEMM
    host_mem_bytes=256 * GB,
    host_mem_bw=55 * GB,               # quad-channel DDR4-2933 effective
    h2d_bw=12.5 * GB, d2h_bw=12.5 * GB,
)

ENV2 = HardwareSpec(
    name="Env#2 RTX4090 PCIe4 EPYC-7542 448G",
    accel_flops=82.6 * TFLOPS * 0.6,
    accel_mem_bytes=24 * GB,
    accel_mem_bw=1008 * GB,
    host_flops=0.7 * TFLOPS,           # 32-core EPYC GEMM
    host_mem_bytes=448 * GB,
    host_mem_bw=120 * GB,              # 8-channel DDR4-3200 effective
    host_attn_eff=0.0022,              # NUMA-penalized (Table 3, 8x22B row)
    h2d_bw=25 * GB, d2h_bw=25 * GB,
)

# Roofline constants from the brief: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI, 16 GB HBM per chip.
TPU_V5E = HardwareSpec(
    name="TPU v5e",
    accel_flops=197 * TFLOPS,
    accel_mem_bytes=16 * GB,
    accel_mem_bw=819 * GB,
    host_flops=0.5 * TFLOPS,
    host_mem_bytes=512 * GB,
    h2d_bw=32 * GB, d2h_bw=32 * GB,    # PCIe gen4-ish host link per chip
    ici_bw=50 * GB,
)

ENVS = {"env1": ENV1, "env2": ENV2, "v5e": TPU_V5E}
