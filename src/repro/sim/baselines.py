"""Analytic performance models of the paper's four baselines (§5.1).

All models share the hardware constants of ``repro.sim.hardware`` and the
byte/FLOP accounting of ``repro.core.planner`` so the *ratios* between
systems follow from structure, not per-system fudge factors:

* **Accelerate** — device-map offloading: every decode step streams all
  non-resident weights host->GPU; attention + FFN on GPU; batch limited by
  the KV cache that must stay in GPU memory alongside the streamed layer.
* **DeepSpeed (ZeRO-Inference)** — full-weight streaming with a pinned
  buffer and slightly better overlap; same structure as Accelerate with a
  bigger feasible batch (its KV can spill to host between steps).
* **FlexGen** — zig-zag column schedule: weights streamed once per batch
  *block* (large effective batch) and decode-phase attention on the CPU
  against host KV; throughput = min(stream-bound, CPU-attention-bound).
* **Fiddler** — MoE-aware CPU/GPU orchestration: attention/shared layers on
  GPU (resident), expert FFNs computed *on the CPU* (no expert streaming);
  bound by host expert GEMM throughput.

Each returns (throughput tok/s, gpu_core_utilization in [0,1], detail).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.planner import (attn_flops_per_token, dense_flops_per_token,
                                kv_bytes_per_token, layer_ffn_bytes)
from repro.sim.hardware import HardwareSpec


@dataclass
class SystemResult:
    name: str
    throughput: float
    gpu_util: float
    detail: dict


# nvidia-smi-style utilization model (calibrated once against Fig 6 / Fig 1):
# SM-active fraction = 0.63 during compute bursts (decode GEMM occupancy),
# 0.12 while the GPU is an I/O endpoint (PCIe copies keep copy+scheduler SMs
# ticking), 0.07 while waiting on CPU compute.
UTIL_COMPUTE, UTIL_IO, UTIL_WAIT = 0.63, 0.12, 0.07


def nvsmi_util(compute_frac: float, io_frac: float = 0.0,
               wait_frac: float = 0.0) -> float:
    return min(1.0, UTIL_COMPUTE * compute_frac + UTIL_IO * io_frac
               + UTIL_WAIT * wait_frac)


def _resident_bytes(hw: HardwareSpec, frac: float = 0.7) -> float:
    """Weights that fit permanently in accelerator memory."""
    return hw.accel_mem_bytes * frac


def accelerate(cfg: ModelConfig, hw: HardwareSpec, prompt_len: int,
               gen_len: int, batch: int = 32) -> SystemResult:
    w = cfg.param_bytes()
    resident = min(w, _resident_bytes(hw, 0.5))     # rest of HBM: KV + act
    stream = max(w - resident, 0.0)
    ctx = prompt_len + gen_len / 2
    t_stream = stream / hw.h2d_bw
    t_gpu = batch * (dense_flops_per_token(cfg)
                     + attn_flops_per_token(cfg, int(ctx))) / hw.accel_flops
    t_tok = t_stream + t_gpu                        # no overlap (HF loop)
    thr = batch / t_tok
    util = nvsmi_util(t_gpu / t_tok, t_stream / t_tok)
    return SystemResult("accelerate", thr, util,
                        {"t_stream": t_stream, "t_gpu": t_gpu,
                         "batch": batch})


def deepspeed(cfg: ModelConfig, hw: HardwareSpec, prompt_len: int,
              gen_len: int, batch: int = 40) -> SystemResult:
    w = cfg.param_bytes()
    resident = min(w, _resident_bytes(hw, 0.4))
    stream = max(w - resident, 0.0)
    ctx = prompt_len + gen_len / 2
    t_stream = stream / hw.h2d_bw
    t_gpu = batch * (dense_flops_per_token(cfg)
                     + attn_flops_per_token(cfg, int(ctx))) / hw.accel_flops
    t_tok = max(t_stream, t_gpu) + 0.15 * t_stream  # partial overlap
    thr = batch / t_tok
    util = nvsmi_util(t_gpu / t_tok, t_stream / t_tok)
    return SystemResult("deepspeed", thr, util,
                        {"t_stream": t_stream, "t_gpu": t_gpu,
                         "batch": batch})


def flexgen(cfg: ModelConfig, hw: HardwareSpec, prompt_len: int,
            gen_len: int, batch: int = 64) -> SystemResult:
    """Zig-zag schedule + CPU attention (the paper's strongest baseline)."""
    ctx = prompt_len + gen_len / 2
    # per decode step: stream all FFN layers once for the whole batch
    t_stream = cfg.n_layers * layer_ffn_bytes(cfg) / hw.h2d_bw
    kv_read = batch * ctx * kv_bytes_per_token(cfg)
    t_cpu_attn = max(batch * attn_flops_per_token(cfg, int(ctx))
                     / hw.host_flops,
                     kv_read / (hw.host_mem_bw * hw.host_attn_eff))
    t_gpu = batch * dense_flops_per_token(cfg) / hw.accel_flops
    t_tok = max(t_stream, t_cpu_attn) + t_gpu       # overlapped pipeline
    thr = batch / t_tok
    util = nvsmi_util(t_gpu / t_tok, min(t_stream, t_tok) / t_tok)
    return SystemResult("flexgen", thr, util,
                        {"t_stream": t_stream, "t_cpu_attn": t_cpu_attn,
                         "t_gpu": t_gpu, "batch": batch})


def fiddler(cfg: ModelConfig, hw: HardwareSpec, prompt_len: int,
            gen_len: int, batch: int = 16) -> SystemResult:
    """CPU expert compute for MoE models (no expert streaming)."""
    ctx = prompt_len + gen_len / 2
    if cfg.is_moe:
        d, f = cfg.d_model, cfg.d_ff
        expert_flops = 2 * 3 * d * f * cfg.top_k * cfg.n_layers
        # CPU GEMM on scattered per-expert token groups reaches only a
        # fraction of peak (small tiles, bf16->f32 conversion)
        t_cpu = batch * expert_flops / (hw.host_flops * 0.3)
        t_gpu = batch * (attn_flops_per_token(cfg, int(ctx))
                         + 2 * cfg.n_layers * 4 * d * d) / hw.accel_flops
    else:  # degenerate: behaves like accelerate
        return accelerate(cfg, hw, prompt_len, gen_len, batch)
    t_tok = max(t_cpu, t_gpu) + 0.1 * t_cpu
    thr = batch / t_tok
    util = nvsmi_util(t_gpu / t_tok, 0.0, 1.0 - t_gpu / t_tok)
    return SystemResult("fiddler", thr, util,
                        {"t_cpu_experts": t_cpu, "t_gpu": t_gpu,
                         "batch": batch})


BASELINES = {
    "accelerate": accelerate,
    "deepspeed": deepspeed,
    "flexgen": flexgen,
    "fiddler": fiddler,
}
