"""Hardware models and the discrete-event pipeline simulator used to
reproduce the paper's measured results (Figs 1/2/5/8, Tables 3/4) on
hardware we do not have."""
