import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination on the production meshes, prove per-chip memory fits, and
extract the roofline terms from the compiled artifact.

The two lines above MUST precede every other import (jax locks the device
count on first init).  Do not import this module from test/bench processes
that need a single device — run it as a subprocess:

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per combination the dry-run records (benchmarks/results/dryrun/*.json):
  - lower+compile success,
  - compiled.memory_analysis()  (bytes/device — proves it fits 16 GB),
  - compiled.cost_analysis()    (HLO FLOPs / bytes for §Roofline),
  - collective bytes parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute),
  - the derived roofline terms (see benchmarks/roofline.py).
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"(\w\d+(?:\[[\d,]*\])?(?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"([a-z]+?)(\d*)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
               "s32": 4, "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1}


def shape_bytes(shape_str: str) -> int:
    m = SHAPE_RE.match(shape_str.replace(" ", ""))
    if not m:
        return 0
    kind, bits, dims = m.groups()
    nbytes = max(int(bits) // 8, 1) if bits else 1  # pred/f8 -> 1 byte
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind from optimized HLO.

    Convention: we charge each collective its RESULT size (equal to the
    operand size for all-reduce; the gathered size for all-gather; the
    scattered size for reduce-scatter) — documented in EXPERIMENTS.md.
    """
    totals = {}
    counts = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # lines look like: %name = bf16[8,128]{1,0} all-gather(...)
        m = re.search(
            r"=\s+(?:\()?([a-z]+\d*\[[\d,]*\][^ ]*)\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        sh, kind = m.groups()
        b = shape_bytes(sh)
        totals[kind] = totals.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": totals, "counts": counts,
            "total_bytes": sum(totals.values())}


def run_one(arch: str, shape_name: str, mesh_kind: str,
            kv_int8: bool = False) -> dict:
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    from repro.launch.mesh import activate_mesh, make_production_mesh
    from repro.launch.specs import applicable, build_step

    cfg = get_config(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = INPUT_SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "phase": shape.phase}
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = 512 if mesh_kind == "multi" else 256
    t0 = time.time()
    with activate_mesh(mesh):
        fn, args, donate = build_step(cfg, shape, mesh)
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_rec = {}
        for k in ("generated_code_size_in_bytes",
                  "argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_rec[k] = int(v)
        cost = compiled.cost_analysis() or {}
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and (
                        "flops" in k or "bytes" in k or k in ("utilization",))}

        hlo = compiled.as_text()
        coll = parse_collectives(hlo)

    per_dev_bytes = (mem_rec.get("argument_size_in_bytes", 0)
                     + mem_rec.get("output_size_in_bytes", 0)
                     + mem_rec.get("temp_size_in_bytes", 0)
                     - mem_rec.get("alias_size_in_bytes", 0))
    rec.update(
        status="ok", n_devices=n_dev,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=mem_rec, per_device_bytes=per_dev_bytes,
        per_device_gib=round(per_dev_bytes / 2**30, 3),
        fits_16gib=bool(per_dev_bytes <= 16 * 2**30),
        cost=cost_rec, collectives=coll,
    )
    return rec


def result_path(arch, shape, mesh_kind):
    return RESULTS_DIR / mesh_kind / f"{arch}__{shape}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) as subprocesses")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (results not cached)")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCHS
        from repro.configs.base import INPUT_SHAPES
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        combos = [(a, s, m) for m in meshes for a in ARCHS
                  for s in INPUT_SHAPES]
        failures = []
        for a, s, m in combos:
            out = result_path(a, s, m)
            if out.exists() and not args.force:
                print(f"[skip-cached] {m} {a} {s}")
                continue
            print(f"[run] {m:6s} {a:28s} {s}", flush=True)
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", a, "--shape", s, "--mesh", m],
                capture_output=True, text=True)
            if r.returncode != 0:
                failures.append((a, s, m))
                print(r.stdout[-2000:])
                print(r.stderr[-4000:])
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    rec = run_one(args.arch, args.shape, args.mesh, kv_int8=args.kv_int8)
    if not args.kv_int8:   # variants are printed, not cached
        out = result_path(args.arch, args.shape, args.mesh)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: rec[k] for k in rec
                      if k not in ("cost", "memory", "collectives")},
                     indent=1))
    if rec["status"] == "ok":
        print("memory:", rec["memory"])
        print("cost (flops/bytes):",
              {k: v for k, v in rec["cost"].items()
               if k in ("flops", "bytes accessed")})
        print("collectives:", rec["collectives"]["bytes"],
              "total=%.3f GiB" % (rec["collectives"]["total_bytes"] / 2**30))


if __name__ == "__main__":
    main()
