"""ShapeDtypeStruct input specs + sharding assignments for every
(architecture x input-shape x mesh) combination.

This is the single source of truth the dry-run, the launchers, and the
roofline benchmarks share.  No device allocation happens here — everything
is abstract (the shannon/kernels pattern: weak-type-correct, shardable
stand-ins).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ATTN, SWA, InputShape, ModelConfig
from repro.models import model as M
from repro.models.transformer import cache_specs, decoder_param_specs
from repro.training.optimizer import make_optimizer, opt_state_specs


def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _bspec(mesh):
    ax = batch_axes(mesh)
    return ax if len(ax) > 1 else ax[0]


def train_layout(cfg: ModelConfig, shape: InputShape, mesh):
    """(tokens batch spec, tokens seq spec, sequence-parallel axis).

    Preferred: fully shard the batch over ('data','model') — pure
    FSDP/ZeRO-3, no activation conflicts, tiny per-chip attention.  On the
    multi-pod mesh the global batch (256) doesn't cover 512 chips, so the
    *sequence* shards over 'pod' (seq-on-pod never conflicts with the
    'model'-axis weight sharding).  MoE archs keep the batch off the model
    axis (experts shard there; tokens spread over it inside shard_map).
    """
    multi = "pod" in mesh.axis_names
    if multi:
        return ("pod", "data"), None, "model"
    return "data", None, "model"


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


def abstract_tree(tree_of_arrays_or_specs, mesh, spec_tree):
    """ShapeDtypeStructs for a pytree given matching PartitionSpecs."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=_ns(mesh, s)),
        tree_of_arrays_or_specs, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# abstract params / caches (no allocation: eval_shape)


def podify_specs(spec_tree, mesh):
    """On the multi-pod mesh, widen every 'data' weight-sharding entry to
    ('pod','data') — the pod axis joins the FSDP product, halving per-chip
    parameter/optimizer bytes (DESIGN.md §6)."""
    if "pod" not in mesh.axis_names:
        return spec_tree

    def conv(p):
        out = []
        for s in p:
            if s == "data":
                out.append(("pod", "data"))
            else:
                out.append(s)
        return P(*out)

    return jax.tree.map(conv, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def model_param_specs(cfg: ModelConfig, mesh):
    return podify_specs(
        M.param_specs(cfg, model_size=mesh.shape.get("model", 1)), mesh)


def abstract_params(cfg: ModelConfig, mesh):
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    return abstract_tree(shapes, mesh, model_param_specs(cfg, mesh))


def abstract_opt_state(cfg: ModelConfig, mesh):
    opt_init, _ = make_optimizer(cfg.optimizer)
    pshapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    oshapes = jax.eval_shape(opt_init, pshapes)
    ospecs = opt_state_specs(cfg.optimizer,
                             M.param_specs(cfg,
                                           mesh.shape.get("model", 1)))
    return abstract_tree(oshapes, mesh, podify_specs(ospecs, mesh))


def kv_seq_spec(shape: InputShape, mesh):
    """How the KV-cache sequence axis shards for a decode shape."""
    if shape.name == "long_500k":
        # batch=1: spread the sequence over every mesh axis
        return tuple(mesh.axis_names)
    return "model"


def cache_batch_spec(shape: InputShape, mesh):
    bs = shape.global_batch
    ax = batch_axes(mesh)
    import math
    nb = math.prod(mesh.shape[a] for a in ax)
    if bs % nb == 0:
        return _bspec(mesh)
    if bs % mesh.shape[ax[-1]] == 0:   # data axis only
        return ax[-1]
    return None


def abstract_cache(cfg: ModelConfig, shape: InputShape, mesh):
    from repro.models.transformer import init_cache
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    specs = cache_specs(cfg, cache_batch_spec(shape, mesh),
                        kv_seq_spec(shape, mesh))
    return abstract_tree(shapes, mesh, specs)


# ---------------------------------------------------------------------------
# model inputs


def input_specs(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    """Abstract model inputs for one (arch, input-shape) pair.

    train  -> {'batch': {tokens[, encoder_frames]}}
    prefill-> {'tokens'[, 'encoder_frames'], 'cache'}
    decode -> {'cache', 'tokens'} (one new token per sequence)
    """
    b, s = shape.global_batch, shape.seq_len
    bspec = cache_batch_spec(shape, mesh)
    out = {}
    if shape.phase == "train":
        tb, ts, _ = train_layout(cfg, shape, mesh)
        batch = {"tokens": _sds((b, s), jnp.int32, mesh, P(tb, ts))}
        if cfg.encoder_decoder:
            batch["encoder_frames"] = _sds(
                (b, cfg.encoder_len, cfg.d_model), jnp.float32, mesh,
                P(tb, None, None))
        out["batch"] = batch
    elif shape.phase == "prefill":
        out["tokens"] = _sds((b, s), jnp.int32, mesh, P(bspec, None))
        if cfg.encoder_decoder:
            out["encoder_frames"] = _sds(
                (b, cfg.encoder_len, cfg.d_model), jnp.float32, mesh,
                P(bspec, None, None))
        out["cache"] = abstract_cache(cfg, shape, mesh)
    else:  # decode
        out["cache"] = abstract_cache(cfg, shape, mesh)
        out["tokens"] = _sds((b, 1), jnp.int32, mesh, P(bspec, None))
    return out


# ---------------------------------------------------------------------------
# step builders


def build_step(cfg: ModelConfig, shape: InputShape, mesh, lr: float = 1e-4):
    """Returns (fn, kwargs_specs, donate_argnames) for jit+lower."""
    from repro.models.layers import sequence_sharding
    from repro.training.train_loop import make_train_step
    ins = input_specs(cfg, shape, mesh)

    if shape.phase == "train":
        accum = pick_accum(cfg, shape, mesh)
        host_opt = cfg.param_count() > 1e11   # ZeRO-Offload for the giants
        step = make_train_step(cfg, mesh, lr, accum_steps=accum,
                               host_optimizer=host_opt)
        _, _, seq_ax = train_layout(cfg, shape, mesh)

        def train_fn(params, opt_state, batch):
            with sequence_sharding(seq_ax):
                return step(params, opt_state, batch)

        args = (abstract_params(cfg, mesh), abstract_opt_state(cfg, mesh),
                ins["batch"])
        return train_fn, args, (0, 1)

    if shape.phase == "prefill":
        if cfg.encoder_decoder:
            def prefill_fn(params, tokens, frames, cache):
                with sequence_sharding("model"):
                    return M.prefill(params, cfg, tokens, cache, mesh,
                                     encoder_frames=frames)
            args = (abstract_params(cfg, mesh), ins["tokens"],
                    ins["encoder_frames"], ins["cache"])
            return prefill_fn, args, (3,)

        def prefill_fn(params, tokens, cache):
            with sequence_sharding("model"):
                return M.prefill(params, cfg, tokens, cache, mesh)
        args = (abstract_params(cfg, mesh), ins["tokens"], ins["cache"])
        return prefill_fn, args, (2,)

    def serve_fn(params, cache, tokens):
        with sequence_sharding(None):
            return M.decode_step(params, cfg, cache, tokens, mesh)

    args = (abstract_params(cfg, mesh), ins["cache"], ins["tokens"])
    return serve_fn, args, (1,)


def pick_accum(cfg: ModelConfig, shape: InputShape, mesh) -> int:
    """Gradient-accumulation steps: keep per-chip microbatch activations
    (B_loc_micro * d_model) within budget for the big dense configs."""
    tb, _, _ = train_layout(cfg, shape, mesh)
    import math
    axes = tb if isinstance(tb, tuple) else (tb,)
    nb = math.prod(mesh.shape[a] for a in axes)
    b_loc = max(1, shape.global_batch // nb)
    target = max(1, (b_loc * cfg.d_model) // 8192)
    accum = 1
    while accum < min(target, b_loc):
        accum *= 2
    return accum


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple:
    """(runs?, reason) — the long_500k skip policy from DESIGN.md §5."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("SKIP(long-context): pure full-attention architecture "
                       "— no sub-quadratic variant (DESIGN.md §5)")
    return True, ""
