"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching SpecOffload serving engine end-to-end at a
reduced scale on this host (CPU), or emits the production sharding plan
for the selected arch on the v5e mesh (``--plan``).

Requests arrive on a Poisson trace (``--rate`` req/s, virtual clock);
the report covers slot occupancy, TTFT / end-to-end latency percentiles,
and sustained tokens/s.

``--async`` serves the same trace through the always-on asyncio front
door instead (:mod:`repro.serving.server`): real clock, two tenants
with weighted fairness + priority preemption, bounded admission queue,
token-by-token streaming, graceful drain.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.base import MISTRAL_7B
from repro.serving.engine import (SchedulerConfig, ServingEngine,
                                  latency_percentiles)
from repro.serving.trace import poisson_requests
from repro.sim.hardware import ENVS


def _serve_async(eng, prompts, gens, args):
    """submit -> stream -> drain through the asyncio front door: an
    open-loop two-tenant Poisson replay with live token streaming."""
    import asyncio

    from repro.serving.engine import latency_percentiles
    from repro.serving.server import AsyncServingServer
    from repro.serving.trace import replay_open_loop, \
        tenant_poisson_requests

    reqs = tenant_poisson_requests(
        prompts, gens, args.rate,
        {"acme": {"share": 2.0, "priority": 1},
         "beta": {"share": 1.0, "priority": 0}})

    async def drive():
        async with AsyncServingServer(eng, max_queue=max(4,
                                                         args.batch * 4)
                                      ) as srv:
            tokens, handles = await replay_open_loop(srv, reqs,
                                                     speed=args.speed)
        return tokens, handles, srv.tenant_report()

    tokens, handles, per_tenant = asyncio.run(drive())
    st = eng.stats()
    toks = sum(len(v) for v in tokens.values() if v is not None)
    print(f"async-served {len(handles)} requests, {toks} streamed "
          f"tokens in {st['wall_s']:.1f}s engine wall "
          f"({eng.throughput(handles):.2f} tok/s, reduced config "
          f"'{eng.target_cfg.name}')")
    print(f"occupancy={st['mean_occupancy']:.2f} over {st['rounds']} "
          f"rounds, fused compiles={st['fused_compiles']}, "
          f"rejected={st['rejected']}, preempted={st['preempted']}, "
          f"drained={not eng.has_work()}")
    for t, d in per_tenant.items():
        print(f"  tenant {t}: {d['requests']} reqs  ttft "
              + "  ".join(f"{k}={v:.3f}s" for k, v in d['ttft_s'].items()))
    pct = latency_percentiles(handles, "latency_s")
    print("  e2e : " + "  ".join(f"{k}={v:.3f}s" for k, v in pct.items()))
    _report_request_obs(eng)


def _report_request_obs(eng):
    """Print the request-timeline summary, SLO compliance and any
    dumped postmortem bundles (when the respective knobs are on)."""
    from repro.obs import timelines_summary
    tls = eng.request_timelines()
    if tls:
        s = timelines_summary(tls)
        print(f"timelines: {s['requests']} reqs  "
              f"queue={s['queue_s_total']:.2f}s  "
              f"prefill={s['prefill_s_total']:.2f}s  "
              f"decode={s['decode_s_total']:.2f}s  "
              f"stall={s['stall_s_total']:.2f}s")
    rep = eng.slo_report()
    if rep is not None:
        for key, c in rep["compliance"].items():
            print(f"  slo {key}: {c['compliance']:.0%} of "
                  f"{c['evaluated']} in objective "
                  f"({c['violations']} violations)")
    if eng.recorder is not None and eng.recorder.bundles:
        for p in eng.recorder.bundles:
            print(f"  postmortem bundle: {p}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--env", default="env1", choices=sorted(ENVS))
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="run the reduced config (CPU-feasible)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--n-cand", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2,
                    help="slots per interleaved half-batch")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate (req/s, virtual clock)")
    ap.add_argument("--admission", default="fifo", choices=("fifo", "sjf"))
    ap.add_argument("--async", dest="run_async", action="store_true",
                    help="serve through the always-on asyncio front "
                         "door (real clock, 2 tenants, bounded "
                         "admission queue, token streaming, drain)")
    ap.add_argument("--speed", type=float, default=8.0,
                    help="arrival-gap compression for --async")
    ap.add_argument("--plan", action="store_true",
                    help="print the ParaSpec plan + placement and exit")
    ap.add_argument("--timelines", action="store_true",
                    help="record per-request phase timelines "
                         "(queue/prefill/decode/stall) and print a "
                         "summary digest")
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="declare a TTFT SLO (seconds); compliance and "
                         "violations are reported at exit")
    ap.add_argument("--slo-e2e", type=float, default=None,
                    help="declare an end-to-end latency SLO (seconds)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="dump flight-recorder postmortem bundles here "
                         "on SLO violations / anomalies")
    args = ap.parse_args()

    tcfg = get_config(args.arch)
    hw = ENVS[args.env]

    if args.plan:
        from repro.core.placement import plan_placement
        from repro.core.planner import ParaSpecPlanner, Workload
        dcfg = MISTRAL_7B
        planner = ParaSpecPlanner(tcfg, dcfg, hw)
        rep = planner.search(Workload(args.prompt_len, args.gen))
        print(f"policy (bs_prefill, bs_decode, bs_draft, n_cand) = "
              f"{rep.policy.astuple()}")
        print(f"predicted throughput = {rep.throughput:.2f} tok/s on "
              f"{hw.name}")
        plan = plan_placement(tcfg, dcfg, hw)
        print(f"placement: hbm={plan.hbm_used/2**30:.1f}G "
              f"host={plan.host_used/2**30:.1f}G "
              f"disk={plan.disk_used/2**30:.1f}G")
        for n in plan.notes:
            print(" note:", n)
        return

    slos = []
    if args.slo_ttft is not None:
        slos.append({"name": "ttft", "metric": "ttft_s",
                     "threshold_s": args.slo_ttft})
    if args.slo_e2e is not None:
        slos.append({"name": "e2e", "metric": "e2e_s",
                     "threshold_s": args.slo_e2e})
    tcfg = tcfg.reduced(d_model=128)
    dcfg = MISTRAL_7B.reduced(d_model=64, vocab=tcfg.vocab_size)
    eng = ServingEngine(tcfg, dcfg, hw,
                        config=SchedulerConfig(
                            max_batch=args.batch, n_cand=args.n_cand,
                            admission=args.admission,
                            clock="real" if args.run_async else "virtual",
                            qos=args.run_async, preempt=args.run_async,
                            tenant_weights={"acme": 2.0, "beta": 1.0},
                            request_timeline=args.timelines,
                            slos=tuple(slos),
                            postmortem_dir=args.postmortem_dir))
    eng.init_from_seed(0)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tcfg.vocab_size,
                            args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]
    gens = rng.integers(max(2, args.gen // 2), args.gen + 1, args.requests)

    if args.run_async:
        _serve_async(eng, prompts, gens.tolist(), args)
        return

    for r in poisson_requests(prompts, gens.tolist(), args.rate):
        eng.submit(r)

    done = eng.run()
    st = eng.stats()
    toks = sum(len(r.result) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in "
          f"{st['wall_s']:.1f}s wall ({eng.throughput(done):.2f} tok/s on "
          f"CPU, reduced config '{tcfg.name}')")
    print(f"occupancy={st['mean_occupancy']:.2f} over {st['rounds']} "
          f"rounds, fused compiles={st['fused_compiles']}")
    for name, attr in (("ttft", "ttft_s"), ("e2e", "latency_s")):
        pct = latency_percentiles(done, attr)
        print(f"{name:>5}: " + "  ".join(f"{k}={v:.3f}s"
                                         for k, v in pct.items()))
    _report_request_obs(eng)


if __name__ == "__main__":
    main()
