"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the SpecOffload serving engine end-to-end at a reduced scale on this
host (CPU), or emits the production sharding plan for the selected arch on
the v5e mesh (``--plan``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.configs.base import MISTRAL_7B
from repro.serving.engine import ServeRequest, ServingEngine
from repro.sim.hardware import ENVS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--env", default="env1", choices=sorted(ENVS))
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="run the reduced config (CPU-feasible)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--n-cand", type=int, default=3)
    ap.add_argument("--plan", action="store_true",
                    help="print the ParaSpec plan + placement and exit")
    args = ap.parse_args()

    tcfg = get_config(args.arch)
    hw = ENVS[args.env]

    if args.plan:
        from repro.core.placement import plan_placement
        from repro.core.planner import ParaSpecPlanner, Workload
        dcfg = MISTRAL_7B
        planner = ParaSpecPlanner(tcfg, dcfg, hw)
        rep = planner.search(Workload(args.prompt_len, args.gen))
        print(f"policy (bs_prefill, bs_decode, bs_draft, n_cand) = "
              f"{rep.policy.astuple()}")
        print(f"predicted throughput = {rep.throughput:.2f} tok/s on "
              f"{hw.name}")
        plan = plan_placement(tcfg, dcfg, hw)
        print(f"placement: hbm={plan.hbm_used/2**30:.1f}G "
              f"host={plan.host_used/2**30:.1f}G "
              f"disk={plan.disk_used/2**30:.1f}G")
        for n in plan.notes:
            print(" note:", n)
        return

    tcfg = tcfg.reduced(d_model=128)
    dcfg = MISTRAL_7B.reduced(d_model=64, vocab=tcfg.vocab_size)
    eng = ServingEngine(tcfg, dcfg, hw, n_cand=args.n_cand, batch_size=2)
    eng.init_from_seed(0)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(ServeRequest(
            i, rng.integers(0, tcfg.vocab_size,
                            args.prompt_len).astype(np.int32),
            max_new_tokens=args.gen))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.result) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.2f} tok/s on CPU, reduced config '{tcfg.name}')")


if __name__ == "__main__":
    main()
