"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this host it trains the reduced config for a few hundred steps on the
synthetic LM stream (the end-to-end driver of deliverable b); with
``--production-plan`` it prints the mesh/sharding/accum decisions the
dry-run uses for the full config.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.pipeline import make_lm_batches
from repro.models import model as M
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--production-plan", action="store_true")
    args = ap.parse_args()

    full = get_config(args.arch)
    if args.production_plan:
        from repro.configs.base import INPUT_SHAPES
        from repro.launch.mesh import make_production_mesh
        from repro.launch.specs import pick_accum, train_layout
        # mesh construction requires the dryrun device-count env; report
        # the decisions symbolically instead of instantiating devices
        print(f"arch={full.name} params={full.param_count()/1e9:.1f}B "
              f"optimizer={full.optimizer} "
              f"offload_carries={full.offload_carries}")
        print("single-pod: batch=P('data'), seq-parallel axis='model', "
              f"accum=per launch/specs.pick_accum")
        print("multi-pod : batch=P(('pod','data')), weights podified "
              "(FSDP over pod+data)")
        return

    cfg = full.reduced(d_model=args.d_model)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_init, _ = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    data = make_lm_batches(args.batch, args.seq, cfg.vocab_size)
    params, opt_state, log = train_loop(cfg, params, opt_state, data,
                                        args.steps, lr=args.lr,
                                        log_every=max(args.steps // 10, 1))
    for row in log:
        print(f"step {row['step']:4d}  loss {row['loss']:.4f}  "
              f"({row['elapsed_s']:.1f}s)")
    first, last = log[0]["loss"], log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first * 0.7 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
