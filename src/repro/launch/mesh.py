"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before the first jax call.

Mesh shapes (TPU v5e):
  single-pod: (data=16, model=16)              — 256 chips
  multi-pod:  (pod=2, data=16, model=16)       — 512 chips
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` only exists on
    newer releases (older ones are Auto-only anyway)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def activate_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` on newer jax, the legacy ``with mesh:`` scope (which
    sets the thread-resources physical mesh) on older releases."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """The mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_devices(mesh) -> int:
    import math
    return math.prod(mesh.shape.values())
