"""Pallas TPU grouped MoE FFN: act(buf @ Wg) * (buf @ Wu) @ Wd per expert.

The expert FFN is the paper's streamed unit (Mixtral experts) and the bulk
of MoE decode FLOPs.  Tiling: grid = (E, C/block_c, F/block_f); each program
computes a (block_c, block_f) SwiGLU tile and accumulates its down-projected
(block_c, D) contribution in VMEM scratch — the (E, C, F) hidden tensor
never exists.  block_f is a 128-multiple for the MXU; D stays whole in VMEM
((block_c, D) f32 accumulator).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _act(h, activation: str):
    if activation == "swiglu":
        return jax.nn.silu(h)
    if activation in ("gelu", "geglu"):
        return jax.nn.gelu(h)
    raise ValueError(activation)


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_scr, *,
            activation: str, n_f_blocks: int):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)          # (bc, D)
    wg = wg_ref[0].astype(jnp.float32)        # (D, bf)
    wu = wu_ref[0].astype(jnp.float32)
    h = _act(x @ wg, activation) * (x @ wu)   # (bc, bf)
    wd = wd_ref[0].astype(jnp.float32)        # (bf, D)
    acc_scr[...] += h @ wd

    @pl.when(fi == n_f_blocks - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_ffn(buf: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, *, activation: str = "swiglu",
            block_c: int = 128, block_f: int = 512,
            interpret: bool = False) -> jax.Array:
    """buf (E, C, D); w_gate/w_up (E, D, F); w_down (E, F, D) -> (E, C, D)."""
    e, c, d = buf.shape
    f = w_gate.shape[2]
    c_p = math.ceil(c / block_c) * block_c
    f_p = math.ceil(f / block_f) * block_f
    if c_p != c:
        buf = jnp.pad(buf, ((0, 0), (0, c_p - c), (0, 0)))
    if f_p != f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, f_p - f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, f_p - f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, f_p - f), (0, 0)))
    ncb, nfb = c_p // block_c, f_p // block_f

    kernel = functools.partial(_kernel, activation=activation,
                               n_f_blocks=nfb)
    out = pl.pallas_call(
        kernel,
        grid=(e, ncb, nfb),
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda ei, ci, fi: (ei, ci, 0)),
            pl.BlockSpec((1, d, block_f), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, d, block_f), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, block_f, d), lambda ei, ci, fi: (ei, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d),
                               lambda ei, ci, fi: (ei, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c_p, d), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, d), jnp.float32)],
        interpret=interpret,
    )(buf, w_gate, w_up, w_down)
    return out[:, :c]
