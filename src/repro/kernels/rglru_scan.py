"""Pallas TPU RG-LRU sequence scan (RecurrentGemma's recurrent hot spot).

The recurrence ``h_t = a_t * h_{t-1} + g_t`` is elementwise over the width
channels, so it parallelizes perfectly across (batch, width) and is
sequential only in time.  Tiling: grid = (B, W/block_w); each program owns a
(S, block_w) slab of gates in VMEM and runs the time loop with the (block_w,)
carry in VMEM scratch — HBM traffic is exactly one read of (a, g) and one
write of h (the op is bandwidth-bound; arithmetic intensity ~1 FLOP/byte).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, g_ref, h0_ref, o_ref, *, seq_len: int):
    a = a_ref[0]            # (S, bw) f32
    g = g_ref[0]
    h0 = h0_ref[0]          # (1, bw) — row vector carry

    def step(t, h):
        h = a[t] * h + g[t]
        o_ref[0, t, :] = h
        return h

    jax.lax.fori_loop(0, seq_len, step, h0[0])


def rglru_scan(a: jax.Array, gated: jax.Array, h0: jax.Array, *,
               block_w: int = 256, interpret: bool = False) -> jax.Array:
    """a/gated (B, S, W) f32 (decay and gated input); h0 (B, W).

    Returns h_all (B, S, W) — the state after every step.
    """
    b, s, w = a.shape
    w_p = math.ceil(w / block_w) * block_w
    if w_p != w:
        pad = ((0, 0), (0, 0), (0, w_p - w))
        a = jnp.pad(a, pad)
        gated = jnp.pad(gated, pad)
        h0 = jnp.pad(h0, ((0, 0), (0, w_p - w)))
    nwb = w_p // block_w

    out = pl.pallas_call(
        functools.partial(_kernel, seq_len=s),
        grid=(b, nwb),
        in_specs=[
            pl.BlockSpec((1, s, block_w), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, s, block_w), lambda bi, wi: (bi, 0, wi)),
            pl.BlockSpec((1, 1, block_w), lambda bi, wi: (bi, 0, wi)),
        ],
        out_specs=pl.BlockSpec((1, s, block_w), lambda bi, wi: (bi, 0, wi)),
        out_shape=jax.ShapeDtypeStruct((b, s, w_p), jnp.float32),
        interpret=interpret,
    )(a, gated, h0[:, None, :])
    return out[..., :w]
