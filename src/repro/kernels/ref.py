"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are small, obviously-correct implementations — the kernels' tests
sweep shapes/dtypes and assert_allclose against them.  They intentionally
materialize full score matrices etc. (oracle clarity over efficiency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, scale=None, causal=True, window=None):
    """q (B,Hq,Sq,d), k/v (B,Hkv,Skv,d) -> (B,Hq,Sq,d)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale=None, window=None,
                         anc_mask=None):
    """q (B,Hq,m,d); k/v (B,Hkv,S,d); lengths (B,). Causal over the m new
    tokens at positions [len-m, len) — or, when ``anc_mask`` (m, m) bool
    is given, ancestor-or-self tree masking of the m-row speculation
    buffer (committed rows < len-m stay fully visible)."""
    b, hq, m, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, g, m, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if anc_mask is not None:
        assert window is None, "tree masking requires full attention"
        am = jnp.asarray(anc_mask)
        kp2 = jnp.arange(skv)[None, :]
        col = kp2 - (lengths[:, None] - m)            # (B, S)
        allowed = jnp.transpose(am[:, jnp.clip(col, 0, m - 1)], (1, 0, 2))
        ok = ((col < 0)[:, None, :]
              | (((col >= 0) & (col < m))[:, None, :] & allowed))
    else:
        kp = jnp.arange(skv)[None, None, :]
        qp = (lengths[:, None, None] - m
              + jnp.arange(m)[None, :, None])        # (B, m, 1)
        ok = (kp <= qp) & (kp < lengths[:, None, None])
        if window is not None:
            ok &= kp > qp - window
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, m, d).astype(q.dtype)


def gather_paged_kv_ref(k_pool, v_pool, block_tables, *, k_scale=None,
                        v_scale=None, dtype=jnp.float32):
    """Materialize per-sequence contiguous KV from a block pool.

    k_pool/v_pool (NB, BS, H, d) [int8 when scales (NB, BS, H, 1) given];
    block_tables (B, MBS) -> k/v (B, MBS*BS, H, d) in ``dtype``.  This is
    the CPU-CI fallback for the paged Pallas kernel *and* the model's
    reference decode path: positions past each sequence's length hold
    garbage and must be masked by the caller.
    """
    nb, bs, h, d = k_pool.shape
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    b, mbs = bt.shape
    idx = (bt[:, :, None] * bs
           + jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(b, -1)
    k = k_pool.reshape(nb * bs, h, d)[idx]
    v = v_pool.reshape(nb * bs, h, d)[idx]
    if k_scale is not None:
        ks = k_scale.reshape(nb * bs, h, 1)[idx]
        vs = v_scale.reshape(nb * bs, h, 1)[idx]
        k = k.astype(jnp.float32) * ks
        v = v.astype(jnp.float32) * vs
    return k.astype(dtype), v.astype(dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               k_scale=None, v_scale=None, scale=None,
                               anc_mask=None):
    """Oracle for the paged kernel: gather, then contiguous decode ref."""
    k, v = gather_paged_kv_ref(k_pool, v_pool, block_tables,
                               k_scale=k_scale, v_scale=v_scale,
                               dtype=jnp.float32)
    return decode_attention_ref(q, jnp.swapaxes(k, 1, 2),
                                jnp.swapaxes(v, 1, 2), lengths,
                                scale=scale,
                                anc_mask=anc_mask).astype(q.dtype)


def moe_ffn_ref(buf, w_gate, w_up, w_down, *, activation="swiglu"):
    act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
    buff = buf.astype(jnp.float32)
    h = act(jnp.einsum("ecd,edf->ecf", buff, w_gate.astype(jnp.float32)))
    h = h * jnp.einsum("ecd,edf->ecf", buff, w_up.astype(jnp.float32))
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(jnp.float32))
    return out.astype(buf.dtype)


def rglru_scan_ref(a, gated, h0):
    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.swapaxes(a, 0, 1),
                                    jnp.swapaxes(gated, 0, 1)))
    return jnp.swapaxes(hs, 0, 1)


def wkv6_ref(r, k, v, w, u, s0):
    """r/k/v/w (B,H,S,hd) f32; u (H,hd); s0 (B,H,hd,hd)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t,
                       S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    sw = lambda z: jnp.swapaxes(z, 0, 2).swapaxes(1, 2)  # (B,H,S,..)->(S,B,H,..)
    S, yT = jax.lax.scan(step, s0, (sw(r), sw(k), sw(v), sw(w)))
    y = jnp.swapaxes(jnp.swapaxes(yT, 0, 1), 1, 2)       # -> (B,H,S,hd)
    return y, S
