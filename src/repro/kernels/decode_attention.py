"""Pallas TPU flash-decode: the *verification* attention of SpecOffload.

The target model verifies m = n_cand+1 (<= 16) query tokens per sequence
against a long KV cache — a skinny-q attention that is pure KV-bandwidth.
Tiling: grid = (batch*kv_heads, Skv/block_k); each program holds the full
(g*m, d) query tile for its KV head group in VMEM (g*m is tiny) and streams
(block_k, d) KV tiles from HBM, accumulating online-softmax state in VMEM
scratch.  This is the per-step hot spot of the decode phase (§4.1.2).

:func:`paged_decode_attention` is the block-table variant for the paged KV
substrate: KV lives in a shared block pool ``(num_blocks, block_size, ...)``
and each grid program looks up the physical block for its (sequence,
logical-block) coordinate through a scalar-prefetched block table, so the
DMA itself performs the gather (no per-step contiguous copy of the cache).
Cold blocks may be stored int8 with per-row-per-head scales; dequantization
happens on the VMEM tile after the gather.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, lens_ref, anc_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale: float, block_k: int, n_kv_blocks: int,
            q_offset_from_len, window: int | None, tree: bool):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (gm, d) flattened
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    length = lens_ref[0]                              # valid cache length
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    m_tokens = q_offset_from_len
    if tree:
        # speculation-tree verify: the last m_tokens cache rows hold the
        # BFS buffer; row r's visibility over them is its int32 ancestor
        # bitmask (bit j = buffer row j is an ancestor-or-self).  No
        # gathers — a shift + AND per (q row, k position).
        anc = anc_ref[...]                            # (gm, 1) int32
        spec0 = length - m_tokens                     # buffer start
        col = k_pos - spec0
        bit = jnp.right_shift(anc, jnp.clip(col, 0, 31)) & 1
        ok = (k_pos < spec0) | ((col >= 0) & (k_pos < length) & (bit > 0))
    else:
        # q rows are (g, m) flattened; row r is token r % m, at logical
        # position length - m + (r % m)
        q_tok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % m_tokens
        q_pos = length - m_tokens + q_tok
        ok = (k_pos <= q_pos) & (k_pos < length)
        if window is not None:
            ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, scale: float | None = None,
                     window: int | None = None, block_k: int = 256,
                     anc_bits: jax.Array | None = None,
                     interpret: bool = False) -> jax.Array:
    """Verify-attention against a cache.

    q (B, Hq, m, d) — the m new tokens (already written into the cache at
    positions [len-m, len)); k/v (B, Hkv, S, d) cache; lengths (B,) valid
    cache length per sequence (= pos + m).  Causal within the m new tokens,
    unless ``anc_bits`` (m,) int32 marks them as a speculation-tree buffer:
    token i then attends committed rows plus buffer rows j with bit j of
    ``anc_bits[i]`` set (its ancestors-or-self).  Returns (B, Hq, m, d).
    """
    b, hq, m, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    tree = anc_bits is not None
    if tree and window is not None:
        raise ValueError("tree masking requires full attention")

    skv_p = math.ceil(skv / block_k) * block_k
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    nk = skv_p // block_k

    # flatten (g, m) into one q tile per KV head
    qf = (q.reshape(b, hkv, g, m, d).reshape(b * hkv, g * m, d))
    kf = k.reshape(b * hkv, skv_p, d)
    vf = v.reshape(b * hkv, skv_p, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), hkv)
    if tree:  # per-q-row bitmask, repeated across the g heads of the tile
        anc = jnp.tile(anc_bits.astype(jnp.int32), g)[:, None]  # (gm, 1)
    else:
        anc = jnp.zeros((1, 1), jnp.int32)

    kernel = functools.partial(
        _kernel, scale=scale, block_k=block_k, n_kv_blocks=nk,
        q_offset_from_len=m, window=window, tree=tree)

    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, nk),
        in_specs=[
            pl.BlockSpec((1, g * m, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1,), lambda bh, ki: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(anc.shape, lambda bh, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g * m, d), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g * m, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * m, 1), jnp.float32),
            pltpu.VMEM((g * m, 1), jnp.float32),
            pltpu.VMEM((g * m, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lens, anc)
    return out.reshape(b, hkv, g, m, d).reshape(b, hq, m, d)


# ---------------------------------------------------------------------------
# paged (block-table) variant


def _paged_kernel(bt_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  anc_ref, o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                  block_size: int, n_log_blocks: int, m_tokens: int,
                  quant: bool, tree: bool):
    """One (sequence, kv-head, logical-block) program.

    The physical block was already selected by the scalar-prefetch index
    maps, so ``k_ref``/``v_ref`` hold the gathered (block_size, d) tile.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (gm, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bs, d)
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, 0].astype(jnp.float32)      # (bs, 1) row scales
        v = v * vs_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    length = lens_ref[pl.program_id(0)]               # valid tokens (= pos+m)
    k_pos = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if tree:
        # ancestor-bitmask masking of the BFS buffer (last m_tokens rows);
        # see _kernel
        anc = anc_ref[...]                            # (gm, 1) int32
        spec0 = length - m_tokens
        col = k_pos - spec0
        bit = jnp.right_shift(anc, jnp.clip(col, 0, 31)) & 1
        ok = (k_pos < spec0) | ((col >= 0) & (k_pos < length) & (bit > 0))
    else:
        q_tok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % m_tokens
        q_pos = length - m_tokens + q_tok
        ok = (k_pos <= q_pos) & (k_pos < length)
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_log_blocks - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           scale: float | None = None,
                           anc_bits: jax.Array | None = None,
                           interpret: bool = False) -> jax.Array:
    """Verify-attention against a paged (block-pool) cache.

    q (B, Hq, m, d) — the m new tokens, already written into the pool at
    logical positions [len-m, len); k_pool/v_pool (NB, BS, Hkv, d) shared
    block pool (int8 when ``k_scale``/``v_scale`` (NB, BS, Hkv, 1) are
    given); block_tables (B, MBS) int32 physical block per logical block
    (entries past the sequence's allocation may be 0/-1 — they are never
    attended because positions >= ``lengths`` are masked); lengths (B,)
    valid tokens per sequence (= pos + m).  Full causal attention (no
    sliding-window support — ring layers stay unpaged by design), unless
    ``anc_bits`` (m,) int32 marks the m tokens as a speculation-tree
    buffer (per-row ancestor bitmasks; see :func:`decode_attention`).
    Returns (B, Hq, m, d).
    """
    b, hq, m, d = q.shape
    nb, bs, hkv, _ = k_pool.shape
    mbs = block_tables.shape[1]
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    quant = k_scale is not None
    tree = anc_bits is not None

    # one q tile per (sequence, kv head) — rows (g, m)-flattened as in the
    # contiguous kernel; pools head-major so tiles are (block, head, bs, d)
    qf = q.reshape(b, hkv, g, m, d).reshape(b, hkv, g * m, d)
    kp = k_pool.transpose(0, 2, 1, 3)                 # (NB, Hkv, BS, d)
    vp = v_pool.transpose(0, 2, 1, 3)
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    lens = lengths.astype(jnp.int32)
    if quant:
        ksp = k_scale.transpose(0, 2, 1, 3)           # (NB, Hkv, BS, 1)
        vsp = v_scale.transpose(0, 2, 1, 3)
    else:  # dummy (1,..) operands keep one kernel signature
        ksp = jnp.zeros((1, hkv, bs, 1), jnp.float32)
        vsp = jnp.zeros((1, hkv, bs, 1), jnp.float32)
    if tree:  # per-q-row bitmask, repeated across the g heads of the tile
        anc = jnp.tile(anc_bits.astype(jnp.int32), g)[:, None]  # (gm, 1)
    else:
        anc = jnp.zeros((1, 1), jnp.int32)

    def q_map(bi, h, j, bt_ref, lens_ref):
        return (bi, h, 0, 0)

    def kv_map(bi, h, j, bt_ref, lens_ref):
        return (bt_ref[bi, j], h, 0, 0)

    def sc_map(bi, h, j, bt_ref, lens_ref):
        if quant:
            return (bt_ref[bi, j], h, 0, 0)
        return (0, h, 0, 0)

    def anc_map(bi, h, j, bt_ref, lens_ref):
        return (0, 0)

    kernel = functools.partial(
        _paged_kernel, scale=scale, block_size=bs, n_log_blocks=mbs,
        m_tokens=m, quant=quant, tree=tree)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, mbs),
        in_specs=[
            pl.BlockSpec((1, 1, g * m, d), q_map),
            pl.BlockSpec((1, 1, bs, d), kv_map),
            pl.BlockSpec((1, 1, bs, d), kv_map),
            pl.BlockSpec((1, 1, bs, 1), sc_map),
            pl.BlockSpec((1, 1, bs, 1), sc_map),
            pl.BlockSpec(anc.shape, anc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g * m, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g * m, 1), jnp.float32),
            pltpu.VMEM((g * m, 1), jnp.float32),
            pltpu.VMEM((g * m, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g * m, d), q.dtype),
        interpret=interpret,
    )(bt, lens, qf, kp, vp, ksp, vsp, anc)
    return out.reshape(b, hkv, g, m, d).reshape(b, hq, m, d)
