"""Pallas TPU flash-attention (prefill/train hot spot).

Tiling: grid = (batch*q_heads, Sq/block_q, Skv/block_k); each program owns a
(block_q, head_dim) query tile in VMEM and streams (block_k, head_dim) K/V
tiles; the online-softmax state (m, l, acc) lives in VMEM scratch across the
kv-block axis of the grid (TPU grids iterate minor-most last, so the kv axis
is sequentially accumulated per q tile).  Blocks are 128-multiples to align
with the MXU; GQA is handled by mapping q-head programs onto shared KV heads
in the BlockSpec index maps (no KV duplication in HBM).

The paper's prefill phase is compute-bound on the accelerator (§4.1.1) —
this kernel is that phase's dominant op.  Oracle: ``ref.flash_attention_ref``
(the same math as ``repro.models.attention.attention_chunked``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, n_kv_blocks: int,
            causal: bool, window: int | None, skv_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = k_pos < skv_valid     # padded KV columns never attend
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window is not None:
        ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float | None = None, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q (B, Hq, Sq, d); k/v (B, Hkv, Skv, d) -> (B, Hq, Sq, d).

    Sq/Skv are padded to block multiples internally (padded kv positions are
    masked; padded q rows are sliced off).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale

    sq_p = math.ceil(sq / block_q) * block_q
    skv_p = math.ceil(skv / block_k) * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    # mask padded kv via the causal test when causal; otherwise window/None
    # padded kv columns would attend — mask them by treating them as future
    # positions (k_pos >= skv > any valid q_pos when causal).  For
    # non-causal use we pass an effective window instead.
    nq = sq_p // block_q
    nk = skv_p // block_k

    qf = q.reshape(b * hq, sq_p, d)
    kf = k.reshape(b * hkv, skv_p, d)
    vf = v.reshape(b * hkv, skv_p, d)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, causal=causal, window=window, skv_valid=skv)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, hq, sq_p, d)
    return out[:, :, :sq]
