"""Pallas TPU kernels for the paper's compute hot-spots.

flash_attention  — prefill/train attention (the GPU-bound prefill phase)
decode_attention — skinny-q verification attention against long KV caches
moe_ffn          — grouped expert SwiGLU (the paper's streamed MoE unit)
rglru_scan       — RecurrentGemma RG-LRU time scan
wkv6             — RWKV-6 WKV recurrence

``ops.py`` holds the jit'd wrappers (interpret=True on CPU); ``ref.py`` the
pure-jnp oracles each kernel is allclose-tested against.
"""
