"""Pallas TPU RWKV-6 WKV recurrence (data-dependent decay).

Per head (state S is (hd, hd))::

    y_t = r_t @ (S + u*k_t (x) v_t) ;  S = w_t*S (col-scaled) + k_t (x) v_t

Tiling: grid = (B*H,); each program holds its head's (S, hd) r/k/v/w slabs
in VMEM and the (hd, hd) f32 state in scratch; time is the sequential loop.
hd = 64 keeps the state at 16 KiB — the op is VMEM-resident and
bandwidth-bound on the rkvw streams, matching the RWKV-6 paper's kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref,
            s_scr, *, seq_len: int):
    s_scr[...] = s0_ref[0]

    u = u_ref[0]                      # (1, hd) broadcast row

    def step(t, _):
        r = r_ref[0, t, :]            # (hd,)
        k = k_ref[0, t, :]
        v = v_ref[0, t, :]
        w = w_ref[0, t, :]
        kv = k[:, None] * v[None, :]              # (hd, hd)
        s_eff = s_scr[...] + u[0][:, None] * kv
        y_ref[0, t, :] = r @ s_eff
        s_scr[...] = w[:, None] * s_scr[...] + kv
        return 0

    jax.lax.fori_loop(0, seq_len, step, 0)
    s_out_ref[0] = s_scr[...]


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, s0: jax.Array, *, interpret: bool = False):
    """r/k/v/w (B, H, S, hd) f32; u (H, hd); s0 (B, H, hd, hd).

    Returns (y (B, H, S, hd), s_final (B, H, hd, hd)).
    """
    b, h, s, hd = r.shape
    rf = r.reshape(b * h, s, hd)
    kf = k.reshape(b * h, s, hd)
    vf = v.reshape(b * h, s, hd)
    wf = w.reshape(b * h, s, hd)
    uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, 1, hd)
    sf = s0.reshape(b * h, hd, hd)

    y, s_fin = pl.pallas_call(
        functools.partial(_kernel, seq_len=s),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((b * h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)
    return y.reshape(b, h, s, hd), s_fin.reshape(b, h, hd, hd)
