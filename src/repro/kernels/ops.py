"""jit'd public wrappers for the Pallas kernels.

On a real TPU these dispatch the compiled kernels; on the CPU container
``interpret=True`` executes the kernel bodies in Python for correctness
validation (the repo-wide convention; see DESIGN.md §7).  ``INTERPRET``
defaults to True when no TPU is present.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import (decode_attention as _da, flash_attention as _fa,
                           moe_ffn as _mf, rglru_scan as _rg, wkv6 as _wk)

INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("scale", "causal", "window", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, scale=None, causal=True, window=None,
                    block_q=128, block_k=128, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "window", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, scale=None, window=None,
                     block_k=256, anc_bits=None, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return _da.decode_attention(q, k, v, lengths, scale=scale, window=window,
                                block_k=block_k, anc_bits=anc_bits,
                                interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           k_scale=None, v_scale=None, scale=None,
                           anc_bits=None, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return _da.paged_decode_attention(
        q, k_pool, v_pool, block_tables, lengths, k_scale=k_scale,
        v_scale=v_scale, scale=scale, anc_bits=anc_bits,
        interpret=interpret)


@partial(jax.jit, static_argnames=("activation", "block_c", "block_f",
                                   "interpret"))
def moe_ffn(buf, w_gate, w_up, w_down, *, activation="swiglu", block_c=128,
            block_f=512, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return _mf.moe_ffn(buf, w_gate, w_up, w_down, activation=activation,
                       block_c=block_c, block_f=block_f, interpret=interpret)


@partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan(a, gated, h0, *, block_w=256, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return _rg.rglru_scan(a, gated, h0, block_w=block_w, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, w, u, s0, *, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return _wk.wkv6(r, k, v, w, u, s0, interpret=interpret)
