"""Labeled Counters / Gauges / Histograms with JSON snapshot and
Prometheus text exposition.  Dependency-free (stdlib only).

The registry is the serving stack's metrics backbone: the scheduler
exports queue depth / occupancy / paged-KV block gauges, the pipeline
exports retrace counters, the offload layer exports per-tier transfer
bytes+seconds, and speculative decoding exports per-round acceptance
histograms (see ``repro.serving.engine.ServingEngine.metrics``).

* Instruments are created through :meth:`Registry.counter` /
  :meth:`gauge` / :meth:`histogram` (get-or-create by name, so modules
  can share one instrument without coordination).
* Labels are passed as keyword arguments at observation time:
  ``reg.counter("transfer_bytes_total").inc(n, tier="h2d")``.
* :meth:`Registry.snapshot` returns a plain-JSON dict;
  :meth:`Registry.prometheus_text` emits the text exposition format
  (``# HELP`` / ``# TYPE`` / cumulative ``_bucket{le=...}`` rows) that a
  Prometheus scraper — or the round-trip parser in ``obs/schema.py`` —
  can consume.
* Histograms keep per-bucket counts plus sum/count/min/max and support
  :meth:`Histogram.percentile` (linear interpolation inside the bucket,
  exact when observations sit on bucket bounds — tested).

:data:`NULL_REGISTRY` is the disabled-mode twin: every instrument is a
shared no-op singleton, so a metrics-off engine loop allocates nothing.

Thread discipline: the async front door runs engine rounds in a worker
thread while the event loop may scrape ``snapshot()`` /
``prometheus_text()`` mid-round.  The hot ``inc()``/``observe()`` path
stays **lock-free** (single engine writer; CPython list/dict primitives
are atomic under the GIL) — the registry lock only serializes the cold
paths: instrument creation and snapshot/exposition, which copy every
dict with one C-level ``list(d.items())`` call so a concurrent labelset
insertion can never raise ``dictionary changed size during iteration``.
Histogram reads derive ``count`` from one atomic copy of the bucket
array, so the ``count == +Inf cumulative`` invariant holds even when a
snapshot races an ``observe`` (tested in ``tests/test_obs.py``).
"""
from __future__ import annotations

import math
import threading


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _esc(v) -> str:
    """Escape a label value per the Prometheus text exposition spec
    (0.0.4): backslash, double-quote and line-feed."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_labels(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing per-labelset float."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.values: dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels):
        if amount < 0:
            raise ValueError("counters only go up")
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)

    def snapshot(self):
        # list() is one C call: atomic vs a concurrent inc-new-labelset
        return {_fmt_labels(k) or "": v
                for k, v in list(self.values.items())}

    def expose(self) -> list:
        return [f"{self.name}{_fmt_labels(k)} {_num(v)}"
                for k, v in sorted(list(self.values.items()))]

    kind = "counter"


class Gauge:
    """Set-to-current-value per-labelset float."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.values: dict[tuple, float] = {}

    def set(self, value: float, **labels):
        self.values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels):
        k = _label_key(labels)
        self.values[k] = self.values.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        return self.values.get(_label_key(labels), 0.0)

    def snapshot(self):
        return {_fmt_labels(k) or "": v
                for k, v in list(self.values.items())}

    def expose(self) -> list:
        return [f"{self.name}{_fmt_labels(k)} {_num(v)}"
                for k, v in sorted(list(self.values.items()))]

    kind = "gauge"


#: default buckets suit sub-second pipeline phases (seconds)
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)

#: wider buckets for request-level latencies — per-tenant TTFT and
#: end-to-end histograms reach minutes under queueing (seconds)
LATENCY_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
                   5.0, 10.0, 30.0, 60.0, 120.0)


def acceptance_buckets(n_cand: int) -> tuple:
    """Integer buckets 0..n_cand for accepted-draft-token histograms."""
    return tuple(float(i) for i in range(n_cand + 1))


class Histogram:
    """Prometheus-style cumulative-bucket histogram (+min/max)."""

    def __init__(self, name: str, help: str = "",
                 buckets: tuple = DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.series: dict[tuple, dict] = {}

    def _series(self, labels: dict) -> dict:
        k = _label_key(labels)
        s = self.series.get(k)
        if s is None:
            s = {"counts": [0] * (len(self.buckets) + 1), "sum": 0.0,
                 "count": 0, "min": math.inf, "max": -math.inf}
            self.series[k] = s
        return s

    def observe(self, value: float, **labels):
        s = self._series(labels)
        v = float(value)
        i = len(self.buckets)
        for j, ub in enumerate(self.buckets):   # first bucket with v <= ub
            if v <= ub:
                i = j
                break
        # sum/min/max first, bucket count last: a reader that sees the
        # bucket increment is then guaranteed to see finite min/max
        s["sum"] += v
        s["count"] += 1
        s["min"] = min(s["min"], v)
        s["max"] = max(s["max"], v)
        s["counts"][i] += 1

    # ------------------------------------------------------------------
    def percentile(self, p: float, **labels) -> float:
        """p in [0, 100]: bucket-interpolated percentile.  Exact when the
        observations coincide with bucket upper bounds (e.g. the integer
        acceptance buckets); otherwise accurate to the bucket width."""
        s = self.series.get(_label_key(labels))
        if s is None:
            return float("nan")
        counts = list(s["counts"])        # one atomic copy per read
        count = sum(counts)
        if count == 0:
            return float("nan")
        lo_all, hi_all = s["min"], s["max"]
        rank = (p / 100.0) * count
        cum = 0
        for j, c in enumerate(counts):
            if c == 0:
                continue
            lo = lo_all if j == 0 else self.buckets[j - 1]
            hi = self.buckets[j] if j < len(self.buckets) else hi_all
            if cum + c >= rank:
                frac = (rank - cum) / c
                return min(max(lo + frac * (hi - lo), lo_all), hi_all)
            cum += c
        return hi_all

    def snapshot(self):
        out = {}
        for k, s in list(self.series.items()):
            # copy counts atomically and derive count from the copy so
            # the count == +Inf invariant survives a racing observe()
            counts = list(s["counts"])
            count = sum(counts)
            cum, buckets = 0, {}
            for j, c in enumerate(counts[:-1]):
                cum += c
                buckets[str(self.buckets[j])] = cum
            buckets["+Inf"] = cum + counts[-1]
            out[_fmt_labels(k) or ""] = {
                "buckets": buckets, "sum": s["sum"], "count": count,
                "min": None if count == 0 else s["min"],
                "max": None if count == 0 else s["max"]}
        return out

    def expose(self) -> list:
        lines = []
        for k, s in sorted(list(self.series.items())):
            counts = list(s["counts"])
            cum = 0
            for j, c in enumerate(counts[:-1]):
                cum += c
                lk = k + (("le", _num(self.buckets[j])),)
                lines.append(f"{self.name}_bucket{_fmt_labels(lk)} {cum}")
            lk = k + (("le", "+Inf"),)
            lines.append(f"{self.name}_bucket{_fmt_labels(lk)} "
                         f"{cum + counts[-1]}")
            lines.append(f"{self.name}_sum{_fmt_labels(k)} {_num(s['sum'])}")
            lines.append(f"{self.name}_count{_fmt_labels(k)} "
                         f"{sum(counts)}")
        return lines

    kind = "histogram"


def _num(v: float) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


# ---------------------------------------------------------------------------


class Registry:
    """Get-or-create instrument registry with JSON + Prometheus export.

    The lock guards instrument creation and snapshot/exposition only —
    the per-observation hot path (``inc``/``set``/``observe``) never
    acquires it (see the module docstring's thread discipline).
    """
    enabled = True

    def __init__(self):
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name, cls, help, **kw):
        inst = self._instruments.get(name)   # fast path: exists already
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = cls(name, help, **kw)
                    self._instruments[name] = inst
        if not isinstance(inst, cls):
            raise TypeError(f"{name} already registered as "
                            f"{type(inst).__name__}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help, buckets=buckets)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON dict: {kind: {name: {labelstr: value}}}.
        Copy-under-lock: safe to call from a scrape thread while the
        engine thread observes."""
        with self._lock:
            insts = sorted(list(self._instruments.items()))
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, inst in insts:
            out[inst.kind + "s"][name] = inst.snapshot()
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4); copy-under-lock
        like :meth:`snapshot`."""
        with self._lock:
            insts = sorted(list(self._instruments.items()))
        lines = []
        for name, inst in insts:
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            lines.extend(inst.expose())
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# disabled mode: shared no-op instruments, nothing allocated per call


class _NullInstrument:
    __slots__ = ()

    def inc(self, amount=1.0, **labels):
        return None

    def set(self, value, **labels):
        return None

    def observe(self, value, **labels):
        return None

    def value(self, **labels):
        return 0.0

    def percentile(self, p, **labels):
        return float("nan")


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    enabled = False

    def counter(self, name, help=""):
        return _NULL_INSTRUMENT

    def gauge(self, name, help=""):
        return _NULL_INSTRUMENT

    def histogram(self, name, help="", buckets=DEFAULT_BUCKETS):
        return _NULL_INSTRUMENT

    def snapshot(self):
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def prometheus_text(self):
        return ""


NULL_REGISTRY = NullRegistry()
