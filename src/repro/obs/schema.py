"""Schema validation for the obs subsystem's two export formats.

Dependency-free validators (no jsonschema) shared by the test suite and
the CI smoke step:

* :func:`validate_chrome_trace` — Chrome trace-event JSON object format
  (the Perfetto / ``chrome://tracing`` input): required keys per event
  phase, non-negative ``ts``/``dur``, consistent pid/tid tracks, and a
  ``thread_name`` metadata event for every tid that carries spans.
* :func:`validate_metrics_snapshot` — the registry's JSON snapshot:
  kind sections, histogram bucket monotonicity, ``count`` == ``+Inf``
  cumulative count.
* :func:`parse_prometheus_text` — minimal exposition-format parser used
  by the round-trip test (``# TYPE`` tracking, label unpacking).

Validators return a list of problem strings — empty means valid — so
callers can assert ``== []`` and get every violation at once.

CLI (used by CI after the bench smoke run)::

    python -m repro.obs.schema trace.json metrics.json
"""
from __future__ import annotations

import json
import re

_REQUIRED_BY_PHASE = {
    "X": ("name", "ts", "dur", "pid", "tid"),
    "i": ("name", "ts", "pid", "tid"),
    "C": ("name", "ts", "pid", "tid", "args"),
    "M": ("name", "pid", "args"),
}


def validate_chrome_trace(obj) -> list:
    """Problems with a Chrome trace-event JSON object ([] == valid)."""
    probs = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    named_tids, span_tids = set(), set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            probs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _REQUIRED_BY_PHASE:
            probs.append(f"event {i}: unsupported phase {ph!r}")
            continue
        for key in _REQUIRED_BY_PHASE[ph]:
            if key not in ev:
                probs.append(f"event {i} (ph={ph}): missing {key!r}")
        if ph == "M" and ev.get("name") == "thread_name":
            named_tids.add((ev.get("pid"), ev.get("tid")))
        if ph in ("X", "i", "C"):
            span_tids.add((ev.get("pid"), ev.get("tid")))
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                probs.append(f"event {i}: ts {ts!r} not a number >= 0")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                probs.append(f"event {i}: dur {dur!r} not a number >= 0")
        if ph == "i" and ev.get("s") not in (None, "t", "p", "g"):
            probs.append(f"event {i}: instant scope {ev.get('s')!r}")
    for pidtid in sorted(span_tids - named_tids):
        probs.append(f"track {pidtid} has events but no thread_name "
                     f"metadata")
    return probs


def validate_metrics_snapshot(obj) -> list:
    """Problems with a Registry.snapshot() dict ([] == valid)."""
    probs = []
    if not isinstance(obj, dict):
        return ["snapshot must be an object"]
    for kind in ("counters", "gauges", "histograms"):
        if kind not in obj or not isinstance(obj[kind], dict):
            probs.append(f"missing {kind!r} section")
    for name, series in obj.get("counters", {}).items():
        for labels, v in series.items():
            if not isinstance(v, (int, float)) or v < 0:
                probs.append(f"counter {name}{labels}: {v!r} not >= 0")
    for name, series in obj.get("gauges", {}).items():
        for labels, v in series.items():
            if not isinstance(v, (int, float)):
                probs.append(f"gauge {name}{labels}: {v!r} not a number")
    for name, series in obj.get("histograms", {}).items():
        for labels, h in series.items():
            buckets = h.get("buckets")
            if not isinstance(buckets, dict) or "+Inf" not in buckets:
                probs.append(f"histogram {name}{labels}: no +Inf bucket")
                continue
            cum = list(buckets.values())
            if any(b > a for a, b in zip(cum[1:], cum[:-1])):
                probs.append(f"histogram {name}{labels}: cumulative "
                             f"bucket counts must be non-decreasing")
            if h.get("count") != buckets["+Inf"]:
                probs.append(f"histogram {name}{labels}: count "
                             f"{h.get('count')} != +Inf {buckets['+Inf']}")
    return probs


# ---------------------------------------------------------------------------
# minimal Prometheus exposition parser (round-trip testing)

# Label values are quoted strings with \\, \" and \n escapes (exposition
# format 0.0.4), so the label block is parsed as a sequence of quoted
# strings — a value may legally contain '}' or ','.
_QUOTED = r'"(?:[^"\\]|\\.)*"'
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>(?:[a-zA-Z_][a-zA-Z0-9_]*=" + _QUOTED
    + r",?)*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(
    r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"')


def _unescape_label(v: str) -> str:
    """Invert the exposition-format label escaping (\\\\, \\", \\n)."""
    out, i = [], 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt,
                                                             c + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def parse_prometheus_text(text: str) -> dict:
    """Parse exposition text into ``{name: {"type": t, "samples":
    {(sorted label items): float}}}`` (``_bucket``/``_sum``/``_count``
    series keep their suffixed names)."""
    out: dict = {}
    types: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        name = m.group("name")
        labels = tuple(sorted(
            (lm.group("k"), _unescape_label(lm.group("v")))
            for lm in _LABEL_RE.finditer(m.group("labels") or "")))
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
                break
        entry = out.setdefault(name, {"type": types.get(base, "untyped"),
                                      "samples": {}})
        entry["samples"][labels] = float(m.group("value"))
    return out


# ---------------------------------------------------------------------------
# request timelines (repro.obs.request_trace)

_TIMELINE_SCHEMA = "repro.request_timeline/v1"
_TIMELINE_NUM = ("queue_s", "prefill_s", "decode_s", "stall_s",
                 "preempted_s")
_TIMELINE_INT = ("tokens", "preemptions", "accepted_total",
                 "verify_rounds")


def validate_request_timeline(tl) -> list:
    """Problems with one request-timeline digest ([] == valid)."""
    probs = []
    if not isinstance(tl, dict):
        return ["timeline must be an object"]
    if tl.get("schema") != _TIMELINE_SCHEMA:
        probs.append(f"schema {tl.get('schema')!r} != "
                     f"{_TIMELINE_SCHEMA!r}")
    if not isinstance(tl.get("rid"), int):
        probs.append("rid must be an int")
    rid = tl.get("rid", "?")
    for key in _TIMELINE_NUM:
        v = tl.get(key)
        if not isinstance(v, (int, float)) or v < 0:
            probs.append(f"rid {rid}: {key} {v!r} not a number >= 0")
    for key in _TIMELINE_INT:
        v = tl.get(key)
        if not isinstance(v, int) or v < 0:
            probs.append(f"rid {rid}: {key} {v!r} not an int >= 0")
    rounds = tl.get("per_round")
    if not isinstance(rounds, list):
        probs.append(f"rid {rid}: per_round must be a list")
    else:
        if (isinstance(tl.get("verify_rounds"), int)
                and tl["verify_rounds"] != len(rounds)):
            probs.append(f"rid {rid}: verify_rounds "
                         f"{tl['verify_rounds']} != per_round "
                         f"length {len(rounds)}")
        for i, r in enumerate(rounds):
            if not isinstance(r, dict) or not {"round", "dur_s",
                                               "accepted",
                                               "emitted"} <= set(r):
                probs.append(f"rid {rid}: per_round[{i}] missing keys")
            elif r["dur_s"] < 0 or r["accepted"] < 0 or r["emitted"] < 0:
                probs.append(f"rid {rid}: per_round[{i}] negative field")
        if (not probs and rounds
                and isinstance(tl.get("accepted_total"), int)):
            if sum(r["accepted"] for r in rounds) != tl["accepted_total"]:
                probs.append(f"rid {rid}: accepted_total != sum of "
                             f"per-round accepted")
    return probs


# ---------------------------------------------------------------------------
# postmortem bundles (repro.obs.slo.FlightRecorder)

_BUNDLE_SCHEMA = "repro.postmortem/v1"
_BUNDLE_FILES = ("manifest.json", "trace.json", "metrics.json",
                 "engine.json", "config.json")
_ENGINE_DIGEST_KEYS = ("rounds", "tokens_out", "queue_depth")


def validate_postmortem_bundle(path: str) -> list:
    """Problems with an on-disk postmortem bundle ([] == valid): the
    five section files exist, the manifest matches the schema, the ring
    trace validates as a Chrome trace, the metrics snapshot validates,
    and the engine digest carries its required keys."""
    import os
    probs = []
    if not os.path.isdir(path):
        return [f"{path}: not a directory"]
    objs = {}
    for fname in _BUNDLE_FILES:
        fp = os.path.join(path, fname)
        if not os.path.isfile(fp):
            probs.append(f"missing {fname}")
            continue
        try:
            with open(fp) as f:
                objs[fname] = json.load(f)
        except ValueError as e:
            probs.append(f"{fname}: not valid JSON ({e})")
    man = objs.get("manifest.json")
    if man is not None:
        if man.get("schema") != _BUNDLE_SCHEMA:
            probs.append(f"manifest schema {man.get('schema')!r} != "
                         f"{_BUNDLE_SCHEMA!r}")
        for key in ("reason", "bundle_seq", "ring_rounds"):
            if key not in man:
                probs.append(f"manifest missing {key!r}")
    if "trace.json" in objs:
        probs += [f"trace: {p}"
                  for p in validate_chrome_trace(objs["trace.json"])]
    if "metrics.json" in objs:
        snap = objs["metrics.json"]
        snap = snap.get("metrics", snap)   # accept both wrapper shapes
        if snap:                            # empty == metrics disabled
            probs += [f"metrics: {p}"
                      for p in validate_metrics_snapshot(snap)]
    eng = objs.get("engine.json")
    if eng is not None:
        for key in _ENGINE_DIGEST_KEYS:
            if key not in eng:
                probs.append(f"engine digest missing {key!r}")
    if "config.json" in objs and not isinstance(objs["config.json"],
                                                dict):
        probs.append("config.json must be an object")
    return probs


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="validate obs trace/metrics JSON exports")
    ap.add_argument("trace", help="Chrome trace-event JSON path")
    ap.add_argument("metrics", nargs="?",
                    help="metrics snapshot JSON path (optional)")
    ap.add_argument("--bundle", action="append", default=[],
                    help="postmortem bundle directory to validate "
                         "(repeatable)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        probs = validate_chrome_trace(json.load(f))
    for p in probs:
        print(f"trace: {p}")
    n_events = 0
    with open(args.trace) as f:
        n_events = len(json.load(f).get("traceEvents", []))
    print(f"{args.trace}: {n_events} events, "
          f"{'OK' if not probs else f'{len(probs)} problems'}")
    if args.metrics:
        with open(args.metrics) as f:
            obj = json.load(f)
        # the bench writes {"metrics": snapshot, ...}; accept both shapes
        snap = obj.get("metrics", obj)
        mp = validate_metrics_snapshot(snap)
        for p in mp:
            print(f"metrics: {p}")
        print(f"{args.metrics}: "
              f"{'OK' if not mp else f'{len(mp)} problems'}")
        probs += mp
    for bundle in args.bundle:
        bp = validate_postmortem_bundle(bundle)
        for p in bp:
            print(f"bundle {bundle}: {p}")
        print(f"{bundle}: {'OK' if not bp else f'{len(bp)} problems'}")
        probs += bp
    return 1 if probs else 0


if __name__ == "__main__":
    raise SystemExit(main())
