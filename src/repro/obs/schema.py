"""Schema validation for the obs subsystem's two export formats.

Dependency-free validators (no jsonschema) shared by the test suite and
the CI smoke step:

* :func:`validate_chrome_trace` — Chrome trace-event JSON object format
  (the Perfetto / ``chrome://tracing`` input): required keys per event
  phase, non-negative ``ts``/``dur``, consistent pid/tid tracks, and a
  ``thread_name`` metadata event for every tid that carries spans.
* :func:`validate_metrics_snapshot` — the registry's JSON snapshot:
  kind sections, histogram bucket monotonicity, ``count`` == ``+Inf``
  cumulative count.
* :func:`parse_prometheus_text` — minimal exposition-format parser used
  by the round-trip test (``# TYPE`` tracking, label unpacking).

Validators return a list of problem strings — empty means valid — so
callers can assert ``== []`` and get every violation at once.

CLI (used by CI after the bench smoke run)::

    python -m repro.obs.schema trace.json metrics.json
"""
from __future__ import annotations

import json
import re

_REQUIRED_BY_PHASE = {
    "X": ("name", "ts", "dur", "pid", "tid"),
    "i": ("name", "ts", "pid", "tid"),
    "C": ("name", "ts", "pid", "tid", "args"),
    "M": ("name", "pid", "args"),
}


def validate_chrome_trace(obj) -> list:
    """Problems with a Chrome trace-event JSON object ([] == valid)."""
    probs = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    named_tids, span_tids = set(), set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            probs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _REQUIRED_BY_PHASE:
            probs.append(f"event {i}: unsupported phase {ph!r}")
            continue
        for key in _REQUIRED_BY_PHASE[ph]:
            if key not in ev:
                probs.append(f"event {i} (ph={ph}): missing {key!r}")
        if ph == "M" and ev.get("name") == "thread_name":
            named_tids.add((ev.get("pid"), ev.get("tid")))
        if ph in ("X", "i", "C"):
            span_tids.add((ev.get("pid"), ev.get("tid")))
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                probs.append(f"event {i}: ts {ts!r} not a number >= 0")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                probs.append(f"event {i}: dur {dur!r} not a number >= 0")
        if ph == "i" and ev.get("s") not in (None, "t", "p", "g"):
            probs.append(f"event {i}: instant scope {ev.get('s')!r}")
    for pidtid in sorted(span_tids - named_tids):
        probs.append(f"track {pidtid} has events but no thread_name "
                     f"metadata")
    return probs


def validate_metrics_snapshot(obj) -> list:
    """Problems with a Registry.snapshot() dict ([] == valid)."""
    probs = []
    if not isinstance(obj, dict):
        return ["snapshot must be an object"]
    for kind in ("counters", "gauges", "histograms"):
        if kind not in obj or not isinstance(obj[kind], dict):
            probs.append(f"missing {kind!r} section")
    for name, series in obj.get("counters", {}).items():
        for labels, v in series.items():
            if not isinstance(v, (int, float)) or v < 0:
                probs.append(f"counter {name}{labels}: {v!r} not >= 0")
    for name, series in obj.get("gauges", {}).items():
        for labels, v in series.items():
            if not isinstance(v, (int, float)):
                probs.append(f"gauge {name}{labels}: {v!r} not a number")
    for name, series in obj.get("histograms", {}).items():
        for labels, h in series.items():
            buckets = h.get("buckets")
            if not isinstance(buckets, dict) or "+Inf" not in buckets:
                probs.append(f"histogram {name}{labels}: no +Inf bucket")
                continue
            cum = list(buckets.values())
            if any(b > a for a, b in zip(cum[1:], cum[:-1])):
                probs.append(f"histogram {name}{labels}: cumulative "
                             f"bucket counts must be non-decreasing")
            if h.get("count") != buckets["+Inf"]:
                probs.append(f"histogram {name}{labels}: count "
                             f"{h.get('count')} != +Inf {buckets['+Inf']}")
    return probs


# ---------------------------------------------------------------------------
# minimal Prometheus exposition parser (round-trip testing)

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>[^"]*)"')


def parse_prometheus_text(text: str) -> dict:
    """Parse exposition text into ``{name: {"type": t, "samples":
    {(sorted label items): float}}}`` (``_bucket``/``_sum``/``_count``
    series keep their suffixed names)."""
    out: dict = {}
    types: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        name = m.group("name")
        labels = tuple(sorted(
            (lm.group("k"), lm.group("v"))
            for lm in _LABEL_RE.finditer(m.group("labels") or "")))
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
                break
        entry = out.setdefault(name, {"type": types.get(base, "untyped"),
                                      "samples": {}})
        entry["samples"][labels] = float(m.group("value"))
    return out


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="validate obs trace/metrics JSON exports")
    ap.add_argument("trace", help="Chrome trace-event JSON path")
    ap.add_argument("metrics", nargs="?",
                    help="metrics snapshot JSON path (optional)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        probs = validate_chrome_trace(json.load(f))
    for p in probs:
        print(f"trace: {p}")
    n_events = 0
    with open(args.trace) as f:
        n_events = len(json.load(f).get("traceEvents", []))
    print(f"{args.trace}: {n_events} events, "
          f"{'OK' if not probs else f'{len(probs)} problems'}")
    if args.metrics:
        with open(args.metrics) as f:
            obj = json.load(f)
        # the bench writes {"metrics": snapshot, ...}; accept both shapes
        snap = obj.get("metrics", obj)
        mp = validate_metrics_snapshot(snap)
        for p in mp:
            print(f"metrics: {p}")
        print(f"{args.metrics}: "
              f"{'OK' if not mp else f'{len(mp)} problems'}")
        probs += mp
    return 1 if probs else 0


if __name__ == "__main__":
    raise SystemExit(main())
