"""Request-scoped tracing: per-request span timelines over the serving
stack.

The round-level tracer (:mod:`repro.obs.trace`) measures the *engine* —
GPU busy fraction, pipeline stall, the paper's utilization claim.  A
serving stack is judged per *request*: time queued, time prefilling,
time riding fused decode rounds, time parked by a preemption, TTFT and
inter-token cadence per tenant.  This module attributes every phase of
a request's life to its request ID (minted in
``AsyncServingServer.submit()`` / ``ServingEngine.submit``):

* :class:`RequestTracker` — the engine calls ``on_submit`` /
  ``on_admit`` / ``on_round`` / ``on_preempt`` / ``on_finish`` as the
  request moves through admission, zig-zag prefill, every fused round
  its slot participates in (verify *and* anti-phase draft rounds),
  preemption/resume, and retirement; the async front door adds
  ``on_delivery`` as tokens are flushed to the stream.
* **Per-request Chrome tracks** — when a live span tracer is attached,
  every phase is mirrored onto a ``req:{rid}`` track in the same
  Chrome/Perfetto trace the pipeline spans land in, so one trace shows
  rounds *and* the requests inside them.
* **JSON timeline digest** — :meth:`RequestTracker.timeline` /
  :meth:`timelines` return plain dicts (``queue_s``, ``prefill_s``,
  ``decode_s``, ``stall_s``, ``preempted_s``, ``tokens``, per-round
  acceptance) validated by ``repro.obs.schema.validate_request_timeline``.
  ``stall_s`` is the admitted-to-finished wall time not covered by any
  recorded phase — host scheduling the request sat through.

Zero cost when disabled: :data:`NULL_REQUEST_TRACKER` no-ops every
entry point (``SchedulerConfig(request_timeline=False)``, the default,
keeps the engine loop allocation-free).  Tracking is host-side only —
it never touches jit boundaries, so traced and untraced runs stay
token-identical with one fused compile (tested in
``tests/test_request_obs.py``).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.obs.trace import NULL_TRACER

#: per-request phases, in display order on the request's Chrome track
PHASES = ("queue", "prefill", "decode", "draft_wait", "preempted")


class NullRequestTracker:
    """Disabled tracker: every entry point is an allocation-free no-op."""
    enabled = False

    def on_submit(self, req, wall=None):
        return None

    def on_reject(self, req, reason):
        return None

    def on_admit(self, req, t0, t1, half=0, slot=0, resumed=False):
        return None

    def on_round(self, req, round_idx, t0, t1, accepted=0, emitted=0,
                 role="verify"):
        return None

    def on_preempt(self, req, wall=None):
        return None

    def on_finish(self, req, wall=None):
        return None

    def on_delivery(self, rid, n=1, wall=None):
        return None

    def timeline(self, rid):
        return None

    def timelines(self):
        return []


NULL_REQUEST_TRACKER = NullRequestTracker()


@dataclass
class _ReqState:
    """Live per-request accumulator (wall = perf_counter seconds)."""
    rid: int
    tenant: str
    priority: int
    arrival_s: float              # scheduler clock
    submit_wall: float
    admitted_s: float = float("nan")
    finished_s: float = float("nan")
    first_admit_wall: float = float("nan")
    last_park_wall: float = float("nan")   # submit or preempt -> next admit
    finish_wall: float = float("nan")
    queue_s: float = 0.0          # wall parked before (re-)admission
    prefill_s: float = 0.0
    decode_s: float = 0.0         # fused rounds, verify + draft roles
    preempted_s: float = 0.0
    preemptions: int = 0
    tokens: int = 0
    deliveries: int = 0
    accepted_total: int = 0
    rounds: list = field(default_factory=list)  # per-round records
    rejected: str | None = None


class RequestTracker:
    """Recording tracker; the engine owns one per serving lifetime.

    ``tracer`` (optional) mirrors phases onto per-request Chrome tracks;
    ``clock`` (optional callable) stamps scheduler-clock seconds onto
    the digest; ``max_done`` bounds retained finished timelines (ring —
    a long-lived server never grows without bound).
    """
    enabled = True

    def __init__(self, tracer=None, clock=None, max_done: int = 4096,
                 max_rounds_per_req: int = 4096):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.clock = clock
        self.max_rounds_per_req = max_rounds_per_req
        self._live: dict[int, _ReqState] = {}
        self._done: deque = deque(maxlen=max_done)
        self._done_by_rid: dict[int, dict] = {}

    # ------------------------------------------------------------------
    def _track(self, rid: int) -> str:
        return f"req:{rid}"

    def _span(self, rid: int, name: str, t0: float, t1: float,
              args: dict | None = None):
        if self.tracer.enabled:
            self.tracer.complete(self._track(rid), name, t0, t1,
                                 cat="request", args=args)

    def _now_wall(self, wall):
        return time.perf_counter() if wall is None else wall

    # ------------------------------------------------------------------
    # engine lifecycle hooks

    def on_submit(self, req, wall=None):
        wall = self._now_wall(wall)
        self._live[req.rid] = _ReqState(
            rid=req.rid, tenant=req.tenant, priority=req.priority,
            arrival_s=req.arrival_s, submit_wall=wall,
            last_park_wall=wall)

    def on_reject(self, req, reason):
        st = self._live.pop(req.rid, None)
        if st is None:
            st = _ReqState(rid=req.rid, tenant=req.tenant,
                           priority=req.priority,
                           arrival_s=req.arrival_s,
                           submit_wall=self._now_wall(None))
        st.rejected = reason
        self._retire(st)

    def on_admit(self, req, t0, t1, half=0, slot=0, resumed=False):
        """One admission: ``t0``/``t1`` bound the prefill+splice work
        (wall).  The park interval since submit (or since the preempt
        that parked it) closes here as ``queue`` or ``preempted``."""
        st = self._live.get(req.rid)
        if st is None:
            return
        park = max(0.0, t0 - st.last_park_wall)
        if resumed:
            st.preempted_s += park
            self._span(req.rid, "preempted", st.last_park_wall, t0,
                       {"preemptions": st.preemptions})
        else:
            st.queue_s += park
            self._span(req.rid, "queue", st.last_park_wall, t0,
                       {"tenant": st.tenant})
        st.last_park_wall = float("nan")
        if st.first_admit_wall != st.first_admit_wall:  # NaN: first admit
            st.first_admit_wall = t0
        st.prefill_s += max(0.0, t1 - t0)
        if self.clock is not None and st.admitted_s != st.admitted_s:
            st.admitted_s = float(self.clock())
        self._span(req.rid, "prefill", t0, t1,
                   {"half": half, "slot": slot, "resumed": resumed})

    def on_round(self, req, round_idx, t0, t1, accepted=0, emitted=0,
                 role="verify"):
        """One fused round the request's slot participated in.  ``role``
        is ``"verify"`` (its half was verified: tokens may have been
        emitted) or ``"draft"`` (the anti-phase half: candidates were
        drafted for it — still pipeline work done on its behalf)."""
        st = self._live.get(req.rid)
        if st is None:
            return
        dur = max(0.0, t1 - t0)
        st.decode_s += dur
        if role == "verify":
            st.accepted_total += int(accepted)
            st.tokens += int(emitted)
            if len(st.rounds) < self.max_rounds_per_req:
                st.rounds.append({"round": int(round_idx), "dur_s": dur,
                                  "accepted": int(accepted),
                                  "emitted": int(emitted), "t1": t1})
        self._span(req.rid, role, t0, t1,
                   {"round": int(round_idx), "accepted": int(accepted),
                    "emitted": int(emitted)})

    def on_preempt(self, req, wall=None):
        st = self._live.get(req.rid)
        if st is None:
            return
        st.preemptions += 1
        st.last_park_wall = self._now_wall(wall)
        if self.tracer.enabled:
            self.tracer.instant(self._track(req.rid), "preempted",
                                {"progress": len(req.progress)})

    def on_finish(self, req, wall=None):
        st = self._live.pop(req.rid, None)
        if st is None:
            return
        st.finish_wall = self._now_wall(wall)
        st.tokens = (len(req.result) if req.result is not None
                     else st.tokens)
        if self.clock is not None:
            st.finished_s = float(self.clock())
        self._retire(st)

    def on_delivery(self, rid, n=1, wall=None):
        """Stream delivery (async front door): ``n`` tokens flushed to
        the request's consumer queue."""
        st = self._live.get(rid)
        if st is not None:
            st.deliveries += int(n)
            return
        tl = self._done_by_rid.get(rid)
        if tl is not None:
            tl["deliveries"] = tl.get("deliveries", 0) + int(n)

    # ------------------------------------------------------------------
    def _retire(self, st: _ReqState):
        tl = self._digest(st)
        if len(self._done) == self._done.maxlen and self._done:
            self._done_by_rid.pop(self._done[0]["rid"], None)
        self._done.append(tl)
        self._done_by_rid[st.rid] = tl

    def _digest(self, st: _ReqState) -> dict:
        admitted = st.first_admit_wall
        finish = st.finish_wall
        span_s = (max(0.0, finish - admitted)
                  if admitted == admitted and finish == finish else 0.0)
        stall = max(0.0, span_s - st.prefill_s - st.decode_s
                    - st.preempted_s)
        gaps = inter_token_gaps(st.rounds)
        return {
            "schema": "repro.request_timeline/v1",
            "rid": st.rid, "tenant": st.tenant, "priority": st.priority,
            "arrival_s": st.arrival_s, "admitted_s": st.admitted_s,
            "finished_s": st.finished_s,
            "queue_s": st.queue_s, "prefill_s": st.prefill_s,
            "decode_s": st.decode_s, "stall_s": stall,
            "preempted_s": st.preempted_s,
            "preemptions": st.preemptions,
            "tokens": st.tokens, "deliveries": st.deliveries,
            "accepted_total": st.accepted_total,
            "verify_rounds": len(st.rounds),
            "per_round": [{k: r[k] for k in
                           ("round", "dur_s", "accepted", "emitted")}
                          for r in st.rounds],
            "inter_token_p99_s": (percentile_of(gaps, 99)
                                  if gaps else None),
            "rejected": st.rejected,
        }

    # ------------------------------------------------------------------
    def timeline(self, rid: int) -> dict | None:
        """Digest for one request: finished/rejected requests get their
        final timeline, live ones a provisional one."""
        tl = self._done_by_rid.get(rid)
        if tl is not None:
            return tl
        st = self._live.get(rid)
        return None if st is None else self._digest(st)

    def timelines(self) -> list:
        """Final digests of every retired request, retirement order."""
        return list(self._done)

    def live_count(self) -> int:
        return len(self._live)


# ---------------------------------------------------------------------------


def inter_token_gaps(rounds: list) -> list:
    """Wall gaps between consecutive token emissions, from per-round
    records: every token emitted by a round becomes available at the
    round's end, so the gap series is (a) zeros inside a round for its
    2nd..nth token and (b) the round-to-round wall delta for the first
    token of each emitting round."""
    gaps, prev_t1 = [], None
    for r in rounds:
        n = int(r.get("emitted", 0))
        if n <= 0:
            continue
        t1 = float(r.get("t1", 0.0))
        if prev_t1 is not None:
            gaps.append(max(0.0, t1 - prev_t1))
        gaps.extend([0.0] * (n - 1))
        prev_t1 = t1
    return gaps


def percentile_of(vals: list, p: float) -> float:
    """Nearest-rank percentile of a small python list (no numpy dep)."""
    s = sorted(vals)
    if not s:
        return float("nan")
    k = max(0, min(len(s) - 1, int(-(-p * len(s) // 100)) - 1))
    return float(s[k])


def timelines_summary(timelines: list) -> dict:
    """Aggregate digest over many request timelines (bench export)."""
    done = [t for t in timelines if not t.get("rejected")]
    if not done:
        return {"requests": 0}

    def _tot(key):
        return float(sum(t[key] for t in done))

    return {
        "requests": len(done),
        "rejected": len(timelines) - len(done),
        "tokens": int(sum(t["tokens"] for t in done)),
        "queue_s_total": _tot("queue_s"),
        "prefill_s_total": _tot("prefill_s"),
        "decode_s_total": _tot("decode_s"),
        "stall_s_total": _tot("stall_s"),
        "preempted_s_total": _tot("preempted_s"),
        "accepted_total": int(sum(t["accepted_total"] for t in done)),
        "verify_rounds_total": int(sum(t["verify_rounds"]
                                       for t in done)),
    }
