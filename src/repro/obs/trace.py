"""Low-overhead span tracer for the serving pipeline.

The paper's headline metric is *utilization* — how much of the offload
bubble the interleaved draft fills (§5: 4.49x GPU core utilization).
Measuring that needs per-phase wall time with device fencing, not
end-of-run tokens/s.  This module provides:

* :class:`Tracer` — context-manager spans on named **tracks** (one per
  pipeline phase: ``target_verify``, ``draft_generate``, ``rollback``,
  ``prefill``, ``h2d``/``d2h`` weight/KV streaming, ``kv`` ops,
  ``round``), instant events (replans, admissions, evictions), and
  counter samples.  Timestamps come from ``time.perf_counter`` (CLOCK_
  MONOTONIC); a settable ``virtual_clock`` additionally stamps each
  event with the scheduler's virtual time so trace replays line up with
  request metrics.
* **Honest device timing** — JAX dispatch is asynchronous, so a span
  around a jitted call measures dispatch, not compute.  Inside a span,
  ``sp.fence(arrays)`` calls ``jax.block_until_ready`` before the span
  closes (only when the tracer fences; a no-op otherwise), and the span
  enters a ``jax.profiler.TraceAnnotation`` when available so the same
  phase names show up in XLA profiler dumps.
* **Chrome trace-event export** — :meth:`Tracer.to_chrome_trace`
  returns the JSON object format (``{"traceEvents": [...]}``) loadable
  in Perfetto / ``chrome://tracing``, with one named thread per track.
* :func:`bubble_report` — the paper's utilization metric, derived from
  spans: per round, GPU busy fraction = union of device-category span
  time inside the round / round wall time; pipeline stall (bubble) =
  the remainder.

Zero cost when disabled: :data:`NULL_TRACER` returns one shared no-op
span object from every call — nothing is allocated per round (asserted
by ``tests/test_obs.py``).  Tracer calls sit strictly *outside* jit
boundaries, so enabling tracing never retraces the fused step.
"""
from __future__ import annotations

import threading
import time

try:  # pragma: no cover - exercised indirectly
    import jax as _jax
    from jax.profiler import TraceAnnotation as _TraceAnnotation
    _HAS_JAX = True
except Exception:  # pragma: no cover - obs must import without jax
    _jax = None
    _TraceAnnotation = None
    _HAS_JAX = False

# Canonical pipeline tracks, in display order (Perfetto sorts by tid).
TRACKS = ("round", "target_verify", "draft_generate", "rollback",
          "prefill", "h2d", "d2h", "kv", "admit", "planner")

#: span categories that count as accelerator-busy for bubble accounting
DEVICE_CATS = frozenset({"device"})


class _NullSpan:
    """Shared do-nothing span: the disabled-mode fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, arrays):
        return arrays

    def rename(self, name):
        return self

    def set(self, key, value):
        return self


NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every entry point is allocation-free."""
    enabled = False
    virtual_clock = None

    def span(self, track, name, cat=None):
        return NULL_SPAN

    def instant(self, track, name, args=None):
        return None

    def complete(self, track, name, t0, t1, cat=None, args=None):
        return None

    def counter(self, track, name, value):
        return None

    def to_chrome_trace(self):
        return {"traceEvents": []}


NULL_TRACER = NullTracer()


class _Span:
    __slots__ = ("_tr", "track", "name", "cat", "t0", "t1", "args",
                 "_fence", "_annot")

    def __init__(self, tracer, track, name, cat):
        self._tr = tracer
        self.track = track
        self.name = name
        self.cat = cat
        self.t0 = self.t1 = 0.0
        self.args = None
        self._fence = None
        self._annot = None

    def __enter__(self):
        if self._tr.use_annotations:
            self._annot = _TraceAnnotation(f"{self.track}/{self.name}")
            self._annot.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self._fence is not None and _HAS_JAX:
            _jax.block_until_ready(self._fence)
        self.t1 = time.perf_counter()
        if self._annot is not None:
            self._annot.__exit__(*exc)
        self._tr._record(self)
        return False

    def fence(self, arrays):
        """Block on ``arrays`` at span exit (when the tracer fences) so
        the span measures device compute, not async dispatch."""
        if self._tr.fence_spans:
            self._fence = arrays
        return arrays

    def rename(self, name):
        self.name = name
        return self

    def set(self, key, value):
        """Attach one key to the span's Chrome-trace ``args``."""
        if self.args is None:
            self.args = {}
        self.args[key] = value
        return self


class Tracer:
    """Recording tracer.  See the module docstring for the API."""
    enabled = True

    def __init__(self, fence: bool = True, annotations: bool = False,
                 virtual_clock=None):
        self.fence_spans = fence
        self.use_annotations = annotations and _TraceAnnotation is not None
        self.virtual_clock = virtual_clock   # callable -> scheduler seconds
        self.t0 = time.perf_counter()
        self.events: list[dict] = []         # chrome trace events (us)
        self._tids: dict[str, int] = {}
        # Guards track creation only: event appends are GIL-atomic, and
        # readers (to_chrome_trace / bubble accounting) take one atomic
        # list() copy — the engine's worker thread can keep recording
        # while the asyncio side exports mid-round.
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)          # fast path: known track
        if tid is None:
            with self._lock:
                tid = self._tids.get(track)
                if tid is None:
                    try:
                        tid = TRACKS.index(track)
                    except ValueError:
                        tid = len(TRACKS) + len(self._tids)
                    self._tids[track] = tid
                    self.events.append(
                        {"ph": "M", "name": "thread_name", "pid": 1,
                         "tid": tid, "args": {"name": track}})
        return tid

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def _stamp(self, args: dict | None) -> dict | None:
        if self.virtual_clock is None:
            return args
        args = dict(args) if args else {}
        args["virtual_s"] = float(self.virtual_clock())
        return args

    def _record(self, sp: _Span):
        ev = {"name": sp.name, "ph": "X", "pid": 1, "tid": self._tid(sp.track),
              "ts": self._us(sp.t0),
              "dur": max(0.0, (sp.t1 - sp.t0) * 1e6)}
        if sp.cat:
            ev["cat"] = sp.cat
        args = self._stamp(sp.args)
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ------------------------------------------------------------------
    def span(self, track: str, name: str, cat: str | None = None) -> _Span:
        """Open a complete-event span on ``track`` (context manager)."""
        return _Span(self, track, name, cat)

    def complete(self, track: str, name: str, t0: float, t1: float,
                 cat: str | None = None, args: dict | None = None):
        """Record an already-timed interval (perf_counter seconds) — used
        to mirror the fused step onto both anti-phase tracks."""
        ev = {"name": name, "ph": "X", "pid": 1, "tid": self._tid(track),
              "ts": self._us(t0), "dur": max(0.0, (t1 - t0) * 1e6)}
        if cat:
            ev["cat"] = cat
        args = self._stamp(args)
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, track: str, name: str, args: dict | None = None):
        """Thread-scoped instant event (admission, eviction, replan)."""
        ev = {"name": name, "ph": "i", "s": "t", "pid": 1,
              "tid": self._tid(track),
              "ts": self._us(time.perf_counter())}
        args = self._stamp(args)
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, track: str, name: str, value: float):
        """Chrome counter sample (rendered as a stacked area track)."""
        self.events.append({"name": name, "ph": "C", "pid": 1,
                            "tid": self._tid(track),
                            "ts": self._us(time.perf_counter()),
                            "args": {name: float(value)}})

    # ------------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON (object format), Perfetto-loadable."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.trace",
                              "clock": "CLOCK_MONOTONIC (perf_counter)"}}


# ---------------------------------------------------------------------------
# bubble accounting: the paper's utilization metric, derived from spans


def _union_s(intervals: list[tuple]) -> float:
    """Total length of the union of (t0, t1) intervals, seconds."""
    total, hi = 0.0, None
    for a, b in sorted(intervals):
        if hi is None or a > hi:
            total += b - a
            hi = b
        elif b > hi:
            total += b - hi
            hi = b
    return total


def bubble_report(tracer, round_track: str = "round",
                  round_name: str = "round") -> dict:
    """Per-round GPU busy fraction + pipeline-stall (bubble) accounting.

    A *round* is one ``round_name`` span on ``round_track`` (one
    scheduler iteration: admit -> fused verify+draft -> retire).  Busy
    time is the union of device-category spans overlapping the round
    (union, so the verify/draft anti-phase mirrors of the one fused XLA
    program are not double counted); the stall is the remainder — host
    scheduling, Python bookkeeping, un-overlapped transfers.  ``idle``
    spans (empty engine waiting for arrivals) are excluded from stall
    and summed separately.

    Returns ``{"rounds", "per_round": [{busy_s, stall_s, busy_frac,
    dur_s}...], "busy_s", "stall_s", "idle_s", "wall_s",
    "gpu_busy_frac", "mean_round_busy_frac"}``.
    """
    rounds, idle_s, device = [], 0.0, []
    for ev in list(tracer.events):   # atomic copy: recorder may append
        if ev.get("ph") != "X":
            continue
        t0 = ev["ts"] * 1e-6
        t1 = t0 + ev["dur"] * 1e-6
        track = tracer_track_name(tracer, ev["tid"])
        if track == round_track:
            if ev["name"] == round_name:
                rounds.append((t0, t1))
            elif ev["name"] == "idle":
                idle_s += t1 - t0
        elif ev.get("cat") in DEVICE_CATS:
            device.append((t0, t1))
    per_round = []
    for (r0, r1) in rounds:
        inside = [(max(a, r0), min(b, r1)) for a, b in device
                  if b > r0 and a < r1]
        busy = _union_s(inside)
        dur = r1 - r0
        per_round.append({"dur_s": dur, "busy_s": busy,
                          "stall_s": max(0.0, dur - busy),
                          "busy_frac": busy / dur if dur > 0 else 0.0})
    wall = sum(r["dur_s"] for r in per_round)
    busy = sum(r["busy_s"] for r in per_round)
    stall = sum(r["stall_s"] for r in per_round)
    return {"rounds": len(per_round), "per_round": per_round,
            "busy_s": busy, "stall_s": stall, "idle_s": idle_s,
            "wall_s": wall,
            "gpu_busy_frac": busy / wall if wall > 0 else 0.0,
            "mean_round_busy_frac":
                (sum(r["busy_frac"] for r in per_round) / len(per_round))
                if per_round else 0.0}


def tracer_track_name(tracer, tid: int) -> str | None:
    for name, t in list(tracer._tids.items()):
        if t == tid:
            return name
    return None
