"""Observability: pipeline tracing + metrics for the serving stack.

One facade object (:class:`Obs`) bundles the two backbones every layer
shares:

* ``obs.tracer`` — span tracer exporting Chrome trace-event JSON
  (:mod:`repro.obs.trace`), plus bubble accounting that derives the
  paper's GPU-utilization metric from the recorded spans.
* ``obs.metrics`` — labeled Counter/Gauge/Histogram registry with JSON
  snapshot and Prometheus text exposition (:mod:`repro.obs.metrics`).

Components take ``obs=None`` and fall back to :data:`NULL_OBS`, whose
tracer and registry are shared no-op singletons — the disabled mode is
allocation-free and adds nothing to the engine loop (tested in
``tests/test_obs.py``).  Build a live one with :func:`make_obs`.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.obs.metrics import (NULL_REGISTRY, NullRegistry,  # noqa: F401
                               Registry, acceptance_buckets)
from repro.obs.request_trace import (NULL_REQUEST_TRACKER,  # noqa: F401
                                     NullRequestTracker, RequestTracker,
                                     timelines_summary)
from repro.obs.slo import (SLO, FlightRecorder, SLOMonitor,  # noqa: F401
                           as_slos)
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer,  # noqa: F401
                             bubble_report)


@dataclass(frozen=True)
class Obs:
    """Tracer + metrics registry bundle passed down the serving stack."""
    tracer: Tracer | NullTracer
    metrics: Registry | NullRegistry

    @property
    def enabled(self) -> bool:
        """True when either backbone records anything."""
        return self.tracer.enabled or self.metrics.enabled


NULL_OBS = Obs(NULL_TRACER, NULL_REGISTRY)


def make_obs(trace: bool = False, metrics: bool = True,
             fence: bool = True, annotations: bool = False,
             virtual_clock=None) -> Obs:
    """Build an :class:`Obs`; disabled backbones are the null singletons.

    ``fence`` makes device-phase spans ``jax.block_until_ready`` their
    results for honest timing (slightly serializes dispatch — that is
    the point); ``annotations`` additionally enters
    ``jax.profiler.TraceAnnotation`` per span so phase names appear in
    XLA profiler dumps.
    """
    if not (trace or metrics):
        return NULL_OBS
    tr = Tracer(fence=fence, annotations=annotations,
                virtual_clock=virtual_clock) if trace else NULL_TRACER
    reg = Registry() if metrics else NULL_REGISTRY
    return Obs(tr, reg)
