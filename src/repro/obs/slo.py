"""Declarative SLOs + anomaly-triggered flight recorder.

The request timelines (:mod:`repro.obs.request_trace`) give every
request a measured TTFT, inter-token cadence and end-to-end latency;
this module turns those into *enforced* objectives and keeps an
always-on black box for when they are missed:

* :class:`SLO` — one declarative objective: a metric (``ttft_s``,
  ``e2e_s``, ``queue_s``, ``inter_token_p99_s``), a threshold, and an
  optional tenant / priority-class scope.
* :class:`SLOMonitor` — evaluates SLOs as requests hit first token and
  retirement, emitting ``slo_violations_total{slo,tenant}`` counters,
  ``slo_compliance{slo,tenant}`` gauges (fraction of evaluated requests
  inside the objective), tracer instant events on an ``slo`` track, and
  an ``on_violation`` callback the engine wires to the flight recorder.
* :class:`FlightRecorder` — a bounded ring buffer of recent round
  records + instants that is *always on* (cheap: one small dict per
  round, ``maxlen`` deque).  On an SLO violation or an anomaly signal —
  acceptance-EMA collapse, GPU-busy-fraction drop, queue-depth spike
  (:meth:`FlightRecorder.check`) — it dumps a **postmortem bundle** to
  ``out_dir``: the ring contents rendered as a Chrome trace window
  (``trace.json``), a metrics snapshot (``metrics.json``), the planner/
  scheduler config (``config.json``), an engine state digest
  (``engine.json``) and a ``manifest.json``, all schema-validated by
  :func:`repro.obs.schema.validate_postmortem_bundle`.  A cooldown +
  bundle cap keeps a sustained violation storm from flooding the disk:
  one incident, one bundle.

Everything here is host-side and jit-free; with ``out_dir=None`` the
recorder never touches the filesystem (triggers are still counted).
"""
from __future__ import annotations

import json
import math
import os
import time
from collections import deque
from dataclasses import dataclass

from repro.obs.metrics import NULL_REGISTRY
from repro.obs.trace import NULL_TRACER

#: timeline keys an SLO may target (all seconds)
SLO_METRICS = ("ttft_s", "e2e_s", "queue_s", "inter_token_p99_s")


@dataclass(frozen=True)
class SLO:
    """One declarative service-level objective."""
    name: str
    metric: str                   # one of SLO_METRICS
    threshold_s: float
    tenant: str | None = None     # None: applies to every tenant
    priority: int | None = None   # None: applies to every class

    def __post_init__(self):
        if self.metric not in SLO_METRICS:
            raise ValueError(f"SLO metric must be one of {SLO_METRICS}, "
                             f"got {self.metric!r}")

    def applies(self, tenant: str, priority: int) -> bool:
        return ((self.tenant is None or self.tenant == tenant)
                and (self.priority is None or self.priority == priority))

    def to_dict(self) -> dict:
        return {"name": self.name, "metric": self.metric,
                "threshold_s": self.threshold_s, "tenant": self.tenant,
                "priority": self.priority}


def as_slos(specs) -> tuple:
    """Normalize a config value (SLOs or plain dicts) into SLO tuples."""
    out = []
    for s in specs or ():
        out.append(s if isinstance(s, SLO) else SLO(**s))
    return tuple(out)


class SLOMonitor:
    """Evaluates SLOs over request metrics as they become available.

    ``observe_ttft`` fires at first token (TTFT/queue objectives can be
    violated long before retirement); ``observe_finish`` fires at
    retirement and covers end-to-end + inter-token objectives (the
    latter needs the request's timeline for round records).  Each
    (slo, request) pair is evaluated at most once.
    """

    def __init__(self, slos, metrics=None, tracer=None,
                 on_violation=None):
        self.slos = as_slos(slos)
        self.metrics = metrics if metrics is not None else NULL_REGISTRY
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.on_violation = on_violation
        self._ok: dict[tuple, int] = {}
        self._bad: dict[tuple, int] = {}
        self.violations: list = []

    # ------------------------------------------------------------------
    def _value(self, slo: SLO, req, timeline) -> float | None:
        if slo.metric == "ttft_s":
            return float(req.ttft_s)
        if slo.metric == "e2e_s":
            return float(req.latency_s)
        if slo.metric == "queue_s":
            return float(req.queue_s)
        if timeline is None:
            return None
        v = timeline.get(slo.metric)
        return None if v is None else float(v)

    def _evaluate(self, slo: SLO, req, timeline):
        value = self._value(slo, req, timeline)
        if value is None or value != value:      # unavailable / NaN
            return
        key = (slo.name, req.tenant)
        violated = value > slo.threshold_s
        tally = self._bad if violated else self._ok
        tally[key] = tally.get(key, 0) + 1
        ok = self._ok.get(key, 0)
        bad = self._bad.get(key, 0)
        if self.metrics.enabled:
            self.metrics.gauge(
                "slo_compliance",
                "fraction of evaluated requests meeting the SLO").set(
                    ok / max(ok + bad, 1), slo=slo.name,
                    tenant=req.tenant)
        if not violated:
            return
        event = {"slo": slo.name, "metric": slo.metric,
                 "threshold_s": slo.threshold_s, "value_s": value,
                 "rid": req.rid, "tenant": req.tenant,
                 "priority": req.priority}
        self.violations.append(event)
        if self.metrics.enabled:
            self.metrics.counter(
                "slo_violations_total",
                "requests that missed a declared SLO").inc(
                    1, slo=slo.name, tenant=req.tenant)
        if self.tracer.enabled:
            self.tracer.instant("slo", "violation", dict(event))
        if self.on_violation is not None:
            self.on_violation(slo, event)

    # ------------------------------------------------------------------
    def observe_ttft(self, req):
        """Evaluate TTFT/queue objectives the moment first token lands."""
        for slo in self.slos:
            if (slo.metric in ("ttft_s", "queue_s")
                    and slo.applies(req.tenant, req.priority)):
                self._evaluate(slo, req, None)

    def observe_finish(self, req, timeline=None):
        """Evaluate end-to-end + inter-token objectives at retirement."""
        for slo in self.slos:
            if (slo.metric in ("e2e_s", "inter_token_p99_s")
                    and slo.applies(req.tenant, req.priority)):
                self._evaluate(slo, req, timeline)

    # ------------------------------------------------------------------
    def report(self) -> dict:
        """Compliance per (slo, tenant) + the violation log."""
        out: dict = {"slos": [s.to_dict() for s in self.slos],
                     "compliance": {}, "violations": len(self.violations),
                     "violation_log": list(self.violations[-64:])}
        for key in sorted(set(self._ok) | set(self._bad)):
            ok, bad = self._ok.get(key, 0), self._bad.get(key, 0)
            out["compliance"]["/".join(key)] = {
                "evaluated": ok + bad, "violations": bad,
                "compliance": ok / max(ok + bad, 1)}
        return out


# ---------------------------------------------------------------------------
# flight recorder


#: bundle schema version stamped into every manifest
BUNDLE_SCHEMA = "repro.postmortem/v1"
#: files every postmortem bundle must contain
BUNDLE_FILES = ("manifest.json", "trace.json", "metrics.json",
                "engine.json", "config.json")


class FlightRecorder:
    """Always-on bounded black box + postmortem dumper.

    ``record_round`` appends one small record per scheduler round to a
    ring (``capacity`` rounds); ``record_instant`` logs noteworthy
    one-off events (admissions storms, replans, violations).
    :meth:`check` runs the anomaly detectors against slow-EMA baselines
    learned from the stream itself; :meth:`trigger` dumps the bundle
    (subject to ``cooldown_s`` between dumps and ``max_bundles`` total).

    Anomaly detectors (all need ``warmup`` rounds of baseline first):

    * **acceptance collapse** — mean live-slot acceptance EMA drops
      below ``accept_collapse`` x its learned baseline.
    * **GPU-busy drop** — the round's fused-step fraction of wall time
      falls below ``busy_drop`` x baseline.
    * **queue spike** — queue depth exceeds ``queue_spike`` x baseline
      (plus a +2 absolute guard so tiny queues can't trip it).
    """

    def __init__(self, capacity: int = 256, out_dir: str | None = None,
                 cooldown_s: float = 30.0, max_bundles: int = 4,
                 warmup: int = 16, accept_collapse: float = 0.25,
                 busy_drop: float = 0.25, queue_spike: float = 4.0,
                 ema: float = 0.05):
        self.capacity = capacity
        self.out_dir = out_dir
        self.cooldown_s = cooldown_s
        self.max_bundles = max_bundles
        self.warmup = warmup
        self.accept_collapse = accept_collapse
        self.busy_drop = busy_drop
        self.queue_spike = queue_spike
        self.ema = ema
        self.ring: deque = deque(maxlen=capacity)
        self.instants: deque = deque(maxlen=capacity)
        self.bundles: list = []       # paths of dumped bundles
        self.triggers: list = []      # every trigger, dumped or not
        self._last_dump_wall = -math.inf
        self._seen = 0
        self._base = {"accept": None, "busy": None, "queue": None}

    # ------------------------------------------------------------------
    def record_round(self, rec: dict):
        """One scheduler round; ``rec`` must carry ``round``/``t0``/
        ``t1`` (perf_counter seconds) and may carry anything else."""
        self.ring.append(rec)

    def record_instant(self, name: str, args: dict | None = None,
                       wall: float | None = None):
        self.instants.append({
            "name": name,
            "t": time.perf_counter() if wall is None else wall,
            "args": args or {}})

    # ------------------------------------------------------------------
    def _drift(self, key: str, value: float) -> float | None:
        """Update the slow baseline; return it as it was *before* this
        sample (so a collapsing signal is judged against history)."""
        prev = self._base[key]
        if prev is None:
            self._base[key] = value
        else:
            self._base[key] = (1 - self.ema) * prev + self.ema * value
        return prev

    def check(self, accept_mean: float | None = None,
              busy_frac: float | None = None,
              queue_depth: int | None = None) -> tuple | None:
        """Run the anomaly detectors on this round's signals.  Returns
        ``(reason, args)`` on the first firing detector, else None."""
        self._seen += 1
        hits = []
        if accept_mean is not None:
            base = self._drift("accept", accept_mean)
            if (base is not None and self._seen > self.warmup
                    and base > 1e-6
                    and accept_mean < self.accept_collapse * base):
                hits.append(("accept_collapse",
                             {"accept_mean": accept_mean,
                              "baseline": base}))
        if busy_frac is not None:
            base = self._drift("busy", busy_frac)
            if (base is not None and self._seen > self.warmup
                    and base > 1e-6
                    and busy_frac < self.busy_drop * base):
                hits.append(("busy_drop", {"busy_frac": busy_frac,
                                           "baseline": base}))
        if queue_depth is not None:
            base = self._drift("queue", float(queue_depth))
            if (base is not None and self._seen > self.warmup
                    and queue_depth > self.queue_spike * max(base, 1.0)
                    + 2.0):
                hits.append(("queue_spike", {"queue_depth": queue_depth,
                                             "baseline": base}))
        return hits[0] if hits else None

    # ------------------------------------------------------------------
    def _ring_chrome_trace(self) -> dict:
        """Render the ring + instants as a standalone Chrome trace
        window (timestamps rebased so the window starts at 0)."""
        t0s = ([r["t0"] for r in self.ring]
               + [i["t"] for i in self.instants])
        base = min(t0s) if t0s else 0.0

        def us(t):
            return max(0.0, (t - base) * 1e6)

        events = [
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
             "args": {"name": "flight:rounds"}},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
             "args": {"name": "flight:instants"}},
        ]
        for r in self.ring:
            args = {k: v for k, v in r.items() if k not in ("t0", "t1")}
            events.append({"ph": "X", "name": "round", "pid": 1,
                           "tid": 0, "ts": us(r["t0"]),
                           "dur": max(0.0, (r["t1"] - r["t0"]) * 1e6),
                           "cat": "flight", "args": args})
        for i in self.instants:
            events.append({"ph": "i", "s": "t", "name": i["name"],
                           "pid": 1, "tid": 1, "ts": us(i["t"]),
                           "args": i["args"]})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.slo.FlightRecorder",
                              "window_rounds": len(self.ring)}}

    def trigger(self, reason: str, args: dict | None = None,
                metrics=None, engine=None, config=None) -> str | None:
        """Dump a postmortem bundle for ``reason``.

        ``metrics``/``engine``/``config`` are zero-arg callables (or
        plain dicts) producing the snapshot sections — callables so a
        cooldown-suppressed trigger costs nothing.  Returns the bundle
        directory path, or None when suppressed / ``out_dir`` unset.
        """
        wall = time.perf_counter()
        self.triggers.append({"reason": reason, "args": args or {},
                              "wall": wall})
        if self.out_dir is None:
            return None
        if wall - self._last_dump_wall < self.cooldown_s:
            return None
        if len(self.bundles) >= self.max_bundles:
            return None
        self._last_dump_wall = wall

        def _call(x):
            return x() if callable(x) else (x or {})

        seq = len(self.bundles)
        safe = "".join(c if c.isalnum() or c in "-_" else "_"
                       for c in reason)
        path = os.path.join(self.out_dir, f"postmortem_{seq:03d}_{safe}")
        os.makedirs(path, exist_ok=True)
        manifest = {"schema": BUNDLE_SCHEMA, "reason": reason,
                    "args": args or {}, "bundle_seq": seq,
                    "ring_rounds": len(self.ring),
                    "ring_instants": len(self.instants),
                    "wall_s": wall}
        sections = {"manifest.json": manifest,
                    "trace.json": self._ring_chrome_trace(),
                    "metrics.json": _call(metrics),
                    "engine.json": _call(engine),
                    "config.json": _call(config)}
        for fname, obj in sections.items():
            with open(os.path.join(path, fname), "w") as f:
                json.dump(obj, f, indent=2, default=str)
        self.bundles.append(path)
        return path
