"""The train step + loop.

``make_train_step`` builds the pure (params, opt_state, batch) ->
(params, opt_state, loss) function that the launcher jits with production
shardings and the dry-run lowers.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import make_optimizer


def make_train_step(cfg: ModelConfig, mesh=None, lr: float = 3e-4,
                    accum_steps: int = 1, host_optimizer: bool = False):
    """Build the train step.

    ``accum_steps > 1`` runs the batch as that many sequential microbatches
    with gradient accumulation (the standard production answer when
    per-chip activation memory binds — the >=100B assigned configs at
    global batch 256 on 256 chips).  Accumulation is bf16 to halve the
    accumulator footprint (TPU-standard; the optimizer math is f32).

    ``host_optimizer`` runs the optimizer update under
    ``compute_on('device_host')`` — ZeRO-Offload realized with the same
    HBM<->host streaming the SpecOffload inference engine uses: the f32
    optimizer transients (g^2, factored moments, updated params) live in
    host memory instead of HBM, at the cost of streaming grads/params over
    the host link once per step.
    """
    _, opt_update = make_optimizer(cfg.optimizer)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch, mesh))(params)

    def update(grads, opt_state, params):
        if not host_optimizer:
            return opt_update(grads, opt_state, params, lr)
        from jax.experimental.compute_on import compute_on
        with compute_on("device_host"):
            new_params, new_state = opt_update(grads, opt_state, params, lr)
        return new_params, new_state

    if accum_steps == 1:
        def train_step(params, opt_state, batch):
            loss, grads = grads_of(params, batch)
            params, opt_state = update(grads, opt_state, params)
            return params, opt_state, loss

        return train_step

    def train_step(params, opt_state, batch):
        micro = jax.tree.map(
            lambda a: a.reshape(accum_steps, a.shape[0] // accum_steps,
                                *a.shape[1:]),
            batch)

        def one(gsum, mb):
            loss, g = grads_of(params, mb)
            gsum = jax.tree.map(
                lambda s, gg: s + gg.astype(s.dtype), gsum, g)
            return gsum, loss

        gsum0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                             params)
        gsum, losses = jax.lax.scan(one, gsum0, micro)
        grads = jax.tree.map(lambda g: g / accum_steps, gsum)
        params, opt_state = update(grads, opt_state, params)
        return params, opt_state, losses.mean()

    return train_step


def train_loop(cfg: ModelConfig, params, opt_state, data_iter, steps: int,
               mesh=None, lr: float = 3e-4, log_every: int = 10,
               jit: bool = True):
    """Simple synchronous training loop; returns (params, opt_state, log)."""
    step_fn = make_train_step(cfg, mesh, lr)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    log = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data_iter)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            loss_v = float(loss)
            log.append({"step": i, "loss": loss_v,
                        "elapsed_s": time.time() - t0})
    return params, opt_state, log
