"""Pure-JAX optimizers.

* ``adamw`` — standard AdamW with f32 moments (default).
* ``adafactor`` — factored second moment (Shazeer & Stern 2018), no first
  moment.  Used for the >=400B assigned configs: AdamW's 12 bytes/param of
  state does not fit the 16 GB/chip HBM budget at single-pod sharding
  (DESIGN.md §6), Adafactor's row/col factors are ~0 bytes/param.

State pytrees mirror the param tree so the launcher can shard them with
the same PartitionSpecs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# AdamW


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.01):
    step = state["step"] + 1
    t = step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** t)
        nu_hat = nu / (1 - b2 ** t)
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    outs = [upd(g, m, n, p)
            for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_params = treedef.unflatten([o[0] for o in outs])
    mu = treedef.unflatten([o[1] for o in outs])
    nu = treedef.unflatten([o[2] for o in outs])
    return new_params, {"mu": mu, "nu": nu, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no momentum)


def adafactor_init(params):
    def factors(p):
        if p.ndim >= 2:
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(factors, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, *, decay=0.8, eps=1e-30,
                     clip=1.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if p.ndim >= 2:
            row = beta * v["row"] + (1 - beta) * g2.mean(axis=-1)
            col = beta * v["col"] + (1 - beta) * g2.mean(axis=-2)
            denom = row.mean(axis=-1, keepdims=True)
            rfac = (row / jnp.maximum(denom, eps))[..., None]
            update = g * jax.lax.rsqrt(jnp.maximum(rfac * col[..., None, :],
                                                   eps))
            newv = {"row": row, "col": col}
        else:
            nu = beta * v["v"] + (1 - beta) * g2
            update = g * jax.lax.rsqrt(jnp.maximum(nu, eps))
            newv = {"v": nu}
        norm = jnp.sqrt(jnp.mean(jnp.square(update)))
        update = update / jnp.maximum(1.0, norm / clip)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), newv

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_v = _flatten_states(state["v"], treedef)
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in outs])
    newv = treedef.unflatten([o[1] for o in outs])
    return new_params, {"v": newv, "step": step}


def _flatten_states(vs, treedef):
    """Flatten the v-state tree, where each leaf is a {row,col}|{v} dict."""
    leaves = []

    def rec(node):
        if isinstance(node, dict) and ("row" in node or "v" in node):
            leaves.append(node)
            return
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k])
        elif isinstance(node, (list, tuple)):
            for x in node:
                rec(x)
        else:
            leaves.append(node)

    rec(vs)
    assert len(leaves) == treedef.num_leaves, (len(leaves), treedef.num_leaves)
    return leaves


# ---------------------------------------------------------------------------


def make_optimizer(kind: str):
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(kind)


def opt_state_specs(kind: str, param_specs):
    """PartitionSpecs for the optimizer state, mirroring param specs."""
    from jax.sharding import PartitionSpec as P
    if kind == "adamw":
        return {"mu": param_specs, "nu": param_specs, "step": P()}

    def factors(spec):
        names = tuple(spec) if spec else ()
        # row drops the last dim's axis, col drops the second-to-last
        if len(names) >= 2:
            return {"row": P(*names[:-1]), "col": P(*names[:-2], names[-1])}
        return {"v": P(*names)}

    is_spec = lambda s: isinstance(s, __import__("jax").sharding.PartitionSpec)
    v = jax.tree.map(factors, param_specs, is_leaf=is_spec)
    return {"v": v, "step": P()}
