"""Training substrate: pure-JAX optimizers, the train step, checkpointing,
and the training loop (no optax/flax dependency)."""
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import (adafactor_init, adafactor_update,
                                      adamw_init, adamw_update,
                                      make_optimizer)
from repro.training.train_loop import make_train_step, train_loop
