"""Checkpointing: msgpack-serialized param/optimizer pytrees.

No orbax/flax dependency — leaves are stored as (dtype, shape, raw bytes)
with the treedef reconstructed from a path->leaf mapping, so any of the
framework's nested-dict/tuple pytrees round-trips exactly.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path, tree, step: int = 0):
    """Write the pytree to ``path`` (msgpack)."""
    leaves = _flatten_with_paths(tree)
    payload = {
        "step": step,
        "leaves": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": np.asarray(v).tobytes()}
            for k, v in leaves.items()
        },
    }
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(".tmp")
    tmp.write_bytes(msgpack.packb(payload))
    tmp.replace(p)


def restore_checkpoint(path, like_tree):
    """Restore into the structure of ``like_tree``; returns (tree, step)."""
    payload = msgpack.unpackb(pathlib.Path(path).read_bytes())
    stored = payload["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for pth, like in flat:
        key = "/".join(_path_str(p) for p in pth)
        rec = stored[key]
        arr = np.frombuffer(rec["data"],
                            dtype=np.dtype(rec["dtype"])).reshape(
                                rec["shape"])
        assert tuple(arr.shape) == tuple(like.shape), (key, arr.shape,
                                                       like.shape)
        leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), leaves)
    return tree, payload["step"]
